//! Physically-paged K/V storage — the memory that block tables address.
//!
//! [`super::block_manager::BlockManager`] owns the *accounting* layer of
//! PagedAttention (block tables, refcounts, the prefix cache); this
//! module owns the *storage* layer those tables point into.  K and V each
//! live in one flat pool laid out as
//!
//! ```text
//! [n_blocks × n_layers × block_size × d]
//! ```
//!
//! so a (block, layer) pair names one contiguous `[block_size × d]`
//! **tile** — the unit attention kernels dequantize at a time — and a
//! (block, layer, in-block position) triple names one `d`-element row.
//! A sequence reaches position `p` through its table: `block =
//! table[p / block_size]`, `offset = p % block_size`.  Two tables
//! containing the same [`BlockId`] therefore *share physical memory* — a
//! prefix-cache hit in the block manager is a real aliased read here (of
//! the **packed** payload, whatever the dtype), not a bookkeeping
//! fiction — and attention kernels walk the pool block-by-block exactly
//! as the paper's paged layout prescribes.
//!
//! # Storage dtypes
//!
//! The pool is dtype-parameterized behind [`KvDtype`] — the paper's
//! co-design of memory layout and computation, extended from the weights
//! to the cache itself.  Per `d`-element row (both sides store
//! identically):
//!
//! | dtype | layout per row            | bytes/row (d=64) | drift vs f32 | freed-block poison          |
//! |-------|---------------------------|------------------|--------------|-----------------------------|
//! | `f32` | `d × f32`                 | 256              | 0 (bit-identical) | rows filled with `f32::NAN` |
//! | `f16` | `d × binary16`            | 128              | ≤ 1e-2 relative logit drift | rows filled with `0x7E00` (f16 NaN) |
//! | `kv4` | `d/2` nibble bytes + f32 scale + f32 zero | 40 | pinned empirically (`eval::numerics`) | scale/zero set to NaN — every lane dequantizes to NaN |
//!
//! `f16` rows round-trip through the [`crate::gptq::simd`] converter
//! dispatch (F16C `vcvtph2ps`/`vcvtps2ph` under a vector kernel, the
//! software [`crate::f16::F16`] converter under scalar dispatch).  `kv4`
//! rows are 4-bit affine-quantized **at append time** against their own
//! min/max (`x̂ = zero + code·scale`, codes 0..=15) and dequantized
//! tile-at-a-time into a reused scratch buffer on the attention walk —
//! the SMB-Opt stack-scratch pattern applied to the cache.
//!
//! Quantization is **per row, write-once**: a row's stored bits are a
//! pure function of the values written, never of write history or of
//! neighbors landing later in the same block.  That is what keeps
//! chunked-vs-one-shot prefill and swap-storm-vs-roomy replays
//! bit-identical *within* a dtype (the chaos and property suites run at
//! every dtype) — a shared per-block scale would make stored K/V depend
//! on which rows happened to exist when the scale was chosen.  The
//! cross-dtype accuracy cost is pinned separately by the
//! `eval::numerics` drift harness.
//!
//! Spill ([`PagedKvCache::spill_blocks`]) and restore move the **packed**
//! payload as [`KvSpill`] — swap volume shrinks with the dtype exactly
//! as the pool does.  Freeing is explicit: when the engine reports blocks
//! whose refcount reached zero ([`PagedKvCache::release_blocks`]), debug
//! builds poison their contents (see the table) so any read through a
//! stale table blows up parity tests loudly instead of silently serving
//! a recycled sequence's K/V.  Release is therefore a *return* of
//! memory, not an overwrite convention.

use crate::f16::F16;
use crate::gptq::simd::{f16_dequant_slice, f16_quant_slice};

use super::block_manager::BlockId;

/// Storage dtype of a [`PagedKvCache`] pool (see module docs table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvDtype {
    /// Raw f32 rows — bit-identical to the pre-quantization pool.
    F32,
    /// IEEE binary16 rows (via [`crate::f16::F16`] / F16C).
    F16,
    /// 4-bit affine rows: packed nibbles + per-row f32 scale/zero.
    Kv4,
}

impl KvDtype {
    /// Every dtype, in widening-compression order (`OPT4GPTQ_KV` values,
    /// the CI dtype matrix, and tests iterate this).
    pub const ALL: [KvDtype; 3] = [KvDtype::F32, KvDtype::F16, KvDtype::Kv4];

    /// Stable lowercase name (`--kv-dtype` / `OPT4GPTQ_KV` value, bench
    /// JSON, CI matrix leg).
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Kv4 => "kv4",
        }
    }

    /// Resolve a name (case-insensitive) to a dtype.
    pub fn parse(s: &str) -> Option<KvDtype> {
        KvDtype::ALL.into_iter().find(|d| d.name() == s.to_ascii_lowercase())
    }

    /// Bytes one side stores per `d`-element row.
    pub fn row_bytes(self, d: usize) -> usize {
        match self {
            KvDtype::F32 => d * std::mem::size_of::<f32>(),
            KvDtype::F16 => d * std::mem::size_of::<u16>(),
            // Two codes per byte, plus the per-row f32 scale and zero.
            KvDtype::Kv4 => d.div_ceil(2) + 2 * std::mem::size_of::<f32>(),
        }
    }

    /// Bytes one block occupies across **both** sides and all layers —
    /// the unit capacity planning and spill accounting price in.
    pub fn block_bytes(self, block_size: usize, n_layers: usize, d: usize) -> usize {
        2 * block_size * n_layers * self.row_bytes(d)
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One side's storage (K or V), matching the pool dtype.
#[derive(Debug, Clone)]
enum Pool {
    F32(Vec<f32>),
    /// binary16 bit patterns.
    F16(Vec<u16>),
    /// Per row: `d.div_ceil(2)` nibble bytes in `packed` plus one
    /// `scale`/`zero` pair (`x̂ = zero + code·scale`).
    Kv4 { packed: Vec<u8>, scale: Vec<f32>, zero: Vec<f32> },
}

impl Pool {
    fn new(dtype: KvDtype, rows: usize, d: usize) -> Pool {
        match dtype {
            KvDtype::F32 => Pool::F32(vec![0.0; rows * d]),
            KvDtype::F16 => Pool::F16(vec![0; rows * d]),
            KvDtype::Kv4 => Pool::Kv4 {
                packed: vec![0; rows * d.div_ceil(2)],
                scale: vec![0.0; rows],
                zero: vec![0.0; rows],
            },
        }
    }

    fn resize(&mut self, rows: usize, d: usize) {
        match self {
            Pool::F32(data) => data.resize(rows * d, 0.0),
            Pool::F16(data) => data.resize(rows * d, 0),
            Pool::Kv4 { packed, scale, zero } => {
                packed.resize(rows * d.div_ceil(2), 0);
                scale.resize(rows, 0.0);
                zero.resize(rows, 0.0);
            }
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Pool::F32(data) => data.len() * std::mem::size_of::<f32>(),
            Pool::F16(data) => data.len() * std::mem::size_of::<u16>(),
            Pool::Kv4 { packed, scale, zero } => {
                packed.len() + (scale.len() + zero.len()) * std::mem::size_of::<f32>()
            }
        }
    }

    /// Quantize and store one row (write-once: the stored bits are a
    /// pure function of `src`).
    fn write_row(&mut self, row: usize, d: usize, src: &[f32]) {
        match self {
            Pool::F32(data) => data[row * d..row * d + d].copy_from_slice(src),
            Pool::F16(data) => f16_quant_slice(src, &mut data[row * d..row * d + d]),
            Pool::Kv4 { packed, scale, zero } => {
                let pb = d.div_ceil(2);
                kv4_quant_row(src, &mut packed[row * pb..row * pb + pb], &mut scale[row], &mut zero[row]);
            }
        }
    }

    /// Dequantize one row into `dst`.
    fn read_row(&self, row: usize, d: usize, dst: &mut [f32]) {
        match self {
            Pool::F32(data) => dst.copy_from_slice(&data[row * d..row * d + d]),
            Pool::F16(data) => f16_dequant_slice(&data[row * d..row * d + d], dst),
            Pool::Kv4 { packed, scale, zero } => {
                let pb = d.div_ceil(2);
                kv4_dequant_row(&packed[row * pb..row * pb + pb], scale[row], zero[row], dst);
            }
        }
    }

    /// Dequantize `n_rows` consecutive rows starting at `row0` into
    /// `scratch`, or return the pool slice directly when it is already
    /// f32 (the zero-copy fast path of the attention walk).
    fn read_tile<'a>(&'a self, row0: usize, n_rows: usize, d: usize, scratch: &'a mut [f32]) -> &'a [f32] {
        let len = n_rows * d;
        match self {
            Pool::F32(data) => &data[row0 * d..row0 * d + len],
            Pool::F16(data) => {
                f16_dequant_slice(&data[row0 * d..row0 * d + len], &mut scratch[..len]);
                &scratch[..len]
            }
            Pool::Kv4 { packed, scale, zero } => {
                let pb = d.div_ceil(2);
                for r in 0..n_rows {
                    let row = row0 + r;
                    kv4_dequant_row(
                        &packed[row * pb..row * pb + pb],
                        scale[row],
                        zero[row],
                        &mut scratch[r * d..r * d + d],
                    );
                }
                &scratch[..len]
            }
        }
    }

    /// Poison `n_rows` consecutive rows so any dequantized read yields
    /// NaN (the dtype analogue of the f32 NaN fill — for kv4 the
    /// *reserved poison scale pattern* is a NaN scale/zero pair, which
    /// every code dequantizes through).
    fn poison_rows(&mut self, row0: usize, n_rows: usize, d: usize) {
        match self {
            Pool::F32(data) => data[row0 * d..(row0 + n_rows) * d].fill(f32::NAN),
            Pool::F16(data) => data[row0 * d..(row0 + n_rows) * d].fill(F16::NAN.0),
            Pool::Kv4 { packed, scale, zero } => {
                let pb = d.div_ceil(2);
                packed[row0 * pb..(row0 + n_rows) * pb].fill(0);
                scale[row0..row0 + n_rows].fill(f32::NAN);
                zero[row0..row0 + n_rows].fill(f32::NAN);
            }
        }
    }

    /// Copy `n_rows` packed rows out into a freshly-shaped spill side.
    fn spill_rows(&self, ranges: &[Option<usize>], n_rows: usize, d: usize) -> SpillSide {
        match self {
            Pool::F32(data) => {
                let mut out = vec![0.0; ranges.len() * n_rows * d];
                for (i, r0) in ranges.iter().enumerate() {
                    if let Some(row0) = r0 {
                        out[i * n_rows * d..(i + 1) * n_rows * d]
                            .copy_from_slice(&data[row0 * d..(row0 + n_rows) * d]);
                    }
                }
                SpillSide::F32(out)
            }
            Pool::F16(data) => {
                let mut out = vec![0u16; ranges.len() * n_rows * d];
                for (i, r0) in ranges.iter().enumerate() {
                    if let Some(row0) = r0 {
                        out[i * n_rows * d..(i + 1) * n_rows * d]
                            .copy_from_slice(&data[row0 * d..(row0 + n_rows) * d]);
                    }
                }
                SpillSide::F16(out)
            }
            Pool::Kv4 { packed, scale, zero } => {
                let pb = d.div_ceil(2);
                let mut sp = vec![0u8; ranges.len() * n_rows * pb];
                let mut ss = vec![0.0; ranges.len() * n_rows];
                let mut sz = vec![0.0; ranges.len() * n_rows];
                for (i, r0) in ranges.iter().enumerate() {
                    if let Some(row0) = r0 {
                        sp[i * n_rows * pb..(i + 1) * n_rows * pb]
                            .copy_from_slice(&packed[row0 * pb..(row0 + n_rows) * pb]);
                        ss[i * n_rows..(i + 1) * n_rows]
                            .copy_from_slice(&scale[row0..row0 + n_rows]);
                        sz[i * n_rows..(i + 1) * n_rows]
                            .copy_from_slice(&zero[row0..row0 + n_rows]);
                    }
                }
                SpillSide::Kv4 { packed: sp, scale: ss, zero: sz }
            }
        }
    }

    /// Copy spilled stride `i` back into `n_rows` rows at `row0`.
    fn restore_rows(&mut self, side: &SpillSide, i: usize, row0: usize, n_rows: usize, d: usize) {
        match (self, side) {
            (Pool::F32(data), SpillSide::F32(src)) => {
                data[row0 * d..(row0 + n_rows) * d]
                    .copy_from_slice(&src[i * n_rows * d..(i + 1) * n_rows * d]);
            }
            (Pool::F16(data), SpillSide::F16(src)) => {
                data[row0 * d..(row0 + n_rows) * d]
                    .copy_from_slice(&src[i * n_rows * d..(i + 1) * n_rows * d]);
            }
            (
                Pool::Kv4 { packed, scale, zero },
                SpillSide::Kv4 { packed: sp, scale: ss, zero: sz },
            ) => {
                let pb = d.div_ceil(2);
                packed[row0 * pb..(row0 + n_rows) * pb]
                    .copy_from_slice(&sp[i * n_rows * pb..(i + 1) * n_rows * pb]);
                scale[row0..row0 + n_rows].copy_from_slice(&ss[i * n_rows..(i + 1) * n_rows]);
                zero[row0..row0 + n_rows].copy_from_slice(&sz[i * n_rows..(i + 1) * n_rows]);
            }
            _ => unreachable!("restore_blocks asserts the spill dtype matches the pool"),
        }
    }
}

/// 4-bit affine row quantization against the row's own min/max.  Rows
/// containing any non-finite value — and degenerate ranges whose scale
/// would not be finite — store the reserved NaN scale/zero pattern so
/// every read is loudly NaN rather than silently clamped.
fn kv4_quant_row(src: &[f32], packed: &mut [u8], scale: &mut f32, zero: &mut f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut finite = true;
    for &x in src {
        if !x.is_finite() {
            finite = false;
            break;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let s = (hi - lo) / 15.0;
    packed.fill(0);
    if !finite || !s.is_finite() {
        *scale = f32::NAN;
        *zero = f32::NAN;
        return;
    }
    *scale = s;
    *zero = lo;
    if s > 0.0 {
        let inv = 1.0 / s;
        for (i, &x) in src.iter().enumerate() {
            let code = ((x - lo) * inv).round().clamp(0.0, 15.0) as u8;
            packed[i / 2] |= code << ((i % 2) * 4);
        }
    }
}

fn kv4_dequant_row(packed: &[u8], scale: f32, zero: f32, dst: &mut [f32]) {
    for (i, o) in dst.iter_mut().enumerate() {
        let code = (packed[i / 2] >> ((i % 2) * 4)) & 0xF;
        // A constant row stores scale 0 (codes 0, x̂ = zero); a poisoned
        // row stores scale NaN — both fall out of the one expression.
        *o = zero + code as f32 * scale;
    }
}

/// One side of a [`KvSpill`]: the packed payload of the spilled blocks,
/// in table order, shaped exactly like the pool side it came from.
#[derive(Debug, Clone, PartialEq)]
pub enum SpillSide {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Kv4 { packed: Vec<u8>, scale: Vec<f32>, zero: Vec<f32> },
}

impl SpillSide {
    pub fn bytes(&self) -> usize {
        match self {
            SpillSide::F32(v) => v.len() * std::mem::size_of::<f32>(),
            SpillSide::F16(v) => v.len() * std::mem::size_of::<u16>(),
            SpillSide::Kv4 { packed, scale, zero } => {
                packed.len() + (scale.len() + zero.len()) * std::mem::size_of::<f32>()
            }
        }
    }
}

/// A swapped-out sequence's K/V payload, **still packed** in the pool's
/// dtype: spill volume shrinks with the dtype exactly as residency
/// does, and restore is a copy, never a requantization (so a
/// swap-out/swap-in round trip is bit-exact at every dtype).
#[derive(Debug, Clone, PartialEq)]
pub struct KvSpill {
    dtype: KvDtype,
    n_blocks: usize,
    k: SpillSide,
    v: SpillSide,
}

impl KvSpill {
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Spilled blocks (table order length).
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Host-side bytes this spill occupies (both sides).
    pub fn bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    /// The K side's packed payload (checkpoint serialization reads the
    /// spill through these instead of re-deriving the pool layout).
    pub fn k(&self) -> &SpillSide {
        &self.k
    }

    /// The V side's packed payload.
    pub fn v(&self) -> &SpillSide {
        &self.v
    }

    /// Reassemble a spill from persisted parts (the checkpoint restore
    /// path); shapes are validated when the spill is restored into a
    /// pool, exactly as for a freshly-spilled one.
    pub fn from_parts(dtype: KvDtype, n_blocks: usize, k: SpillSide, v: SpillSide) -> KvSpill {
        KvSpill { dtype, n_blocks, k, v }
    }
}

/// Flat paged K/V pool (see module docs for the layout and dtypes).
#[derive(Debug)]
pub struct PagedKvCache {
    block_size: usize,
    n_layers: usize,
    /// Values per (position, layer) row — the model's `kv_dim =
    /// n_kv_heads · d_head` (equal to `d_model` only for MHA; GQA
    /// backends shrink every row by the Q/KV group ratio).
    d: usize,
    n_blocks: usize,
    dtype: KvDtype,
    k: Pool,
    v: Pool,
}

impl PagedKvCache {
    /// An f32 pool — bit-identical to the pre-[`KvDtype`] cache.
    pub fn new(n_blocks: usize, block_size: usize, n_layers: usize, d: usize) -> PagedKvCache {
        PagedKvCache::with_dtype(n_blocks, block_size, n_layers, d, KvDtype::F32)
    }

    pub fn with_dtype(
        n_blocks: usize,
        block_size: usize,
        n_layers: usize,
        d: usize,
        dtype: KvDtype,
    ) -> PagedKvCache {
        assert!(block_size > 0 && n_layers > 0 && d > 0);
        let rows = n_blocks * n_layers * block_size;
        PagedKvCache {
            block_size,
            n_layers,
            d,
            n_blocks,
            dtype,
            k: Pool::new(dtype, rows, d),
            v: Pool::new(dtype, rows, d),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Bytes held by both pools (dtype-aware capacity accounting).
    pub fn bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    /// Bytes one resident token costs across both sides and all layers —
    /// the per-dtype density figure capacity planning divides by.
    pub fn bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.dtype.row_bytes(self.d)
    }

    /// f32 values in one (block, layer) tile — the scratch size
    /// [`Self::k_block`]/[`Self::v_block`] dequantize into.
    pub fn tile_len(&self) -> usize {
        self.block_size * self.d
    }

    /// Grow the pool so every id `< n_blocks` is addressable (no-op when
    /// already large enough; never shrinks).
    pub fn ensure_blocks(&mut self, n_blocks: usize) {
        if n_blocks > self.n_blocks {
            let rows = n_blocks * self.n_layers * self.block_size;
            self.k.resize(rows, self.d);
            self.v.resize(rows, self.d);
            self.n_blocks = n_blocks;
        }
    }

    /// Row index of one (block, layer, in-block position) cell — layer
    /// outer of position, so a (block, layer) tile is contiguous.
    #[inline]
    fn row_index(&self, block: BlockId, pos_in_block: usize, layer: usize) -> usize {
        debug_assert!(pos_in_block < self.block_size && layer < self.n_layers);
        (block * self.n_layers + layer) * self.block_size + pos_in_block
    }

    /// Rows per block (all layers × all in-block positions).
    #[inline]
    fn rows_per_block(&self) -> usize {
        self.n_layers * self.block_size
    }

    /// Write one position's K and V rows through a block table,
    /// quantizing to the pool dtype at append time.  Grows the pool on
    /// demand so directly-driven backends need no up-front geometry
    /// binding.
    pub fn write(
        &mut self,
        table: &[BlockId],
        pos: usize,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let block = table[pos / self.block_size];
        self.ensure_blocks(block + 1);
        let row = self.row_index(block, pos % self.block_size, layer);
        self.k.write_row(row, self.d, k_row);
        self.v.write_row(row, self.d, v_row);
    }

    /// Dequantized K row of one (block, in-block position, layer) cell,
    /// `d` floats (inspection/test path — the attention walk reads whole
    /// tiles through [`Self::k_block`] instead).
    pub fn k_row(&self, block: BlockId, pos_in_block: usize, layer: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        self.k.read_row(self.row_index(block, pos_in_block, layer), self.d, &mut out);
        out
    }

    /// Dequantized V row of one (block, in-block position, layer) cell.
    pub fn v_row(&self, block: BlockId, pos_in_block: usize, layer: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.d];
        self.v.read_row(self.row_index(block, pos_in_block, layer), self.d, &mut out);
        out
    }

    /// One (block, layer) K tile as `block_size × d` f32s: a zero-copy
    /// borrow of the pool for `f32`, a single-call dequantization into
    /// `scratch` (length ≥ [`Self::tile_len`]) otherwise — the hot unit
    /// of the attention block walk.
    #[inline]
    pub fn k_block<'a>(
        &'a self,
        block: BlockId,
        layer: usize,
        scratch: &'a mut [f32],
    ) -> &'a [f32] {
        let row0 = self.row_index(block, 0, layer);
        self.k.read_tile(row0, self.block_size, self.d, scratch)
    }

    /// One (block, layer) V tile (see [`Self::k_block`]).
    #[inline]
    pub fn v_block<'a>(
        &'a self,
        block: BlockId,
        layer: usize,
        scratch: &'a mut [f32],
    ) -> &'a [f32] {
        let row0 = self.row_index(block, 0, layer);
        self.v.read_tile(row0, self.block_size, self.d, scratch)
    }

    /// Copy the given blocks' **packed** contents out of the pool
    /// (swap-out to a host-side spill buffer), in table order: stride
    /// `i` of the result holds block `blocks[i]`'s full payload.  Blocks
    /// past the pool (allocated but never written) spill as zeros.  Must
    /// run **before** the same blocks are poisoned or recycled — the
    /// engine drains swap-outs ahead of block releases.
    pub fn spill_blocks(&self, blocks: &[BlockId]) -> KvSpill {
        let rpb = self.rows_per_block();
        let ranges: Vec<Option<usize>> = blocks
            .iter()
            .map(|&b| (b < self.n_blocks).then_some(b * rpb))
            .collect();
        KvSpill {
            dtype: self.dtype,
            n_blocks: blocks.len(),
            k: self.k.spill_rows(&ranges, rpb, self.d),
            v: self.v.spill_rows(&ranges, rpb, self.d),
        }
    }

    /// Write spilled contents back into the pool at a (generally new)
    /// set of physical blocks: stride `i` of the spill lands in
    /// `blocks[i]`, preserving table order — a swapped-in sequence reads
    /// the exact packed K/V it swapped out, just at different physical
    /// addresses.  The spill's dtype must match the pool's.
    pub fn restore_blocks(&mut self, blocks: &[BlockId], spill: &KvSpill) {
        assert_eq!(spill.dtype, self.dtype, "spill/pool dtype mismatch");
        assert_eq!(spill.n_blocks, blocks.len(), "spill/table shape mismatch");
        if let Some(&max) = blocks.iter().max() {
            self.ensure_blocks(max + 1);
        }
        let rpb = self.rows_per_block();
        for (i, &b) in blocks.iter().enumerate() {
            self.k.restore_rows(&spill.k, i, b * rpb, rpb, self.d);
            self.v.restore_rows(&spill.v, i, b * rpb, rpb, self.d);
        }
    }

    /// Accept blocks back from the allocator (refcount reached zero).
    /// Debug builds poison the returned memory so stale reads through a
    /// dangling table surface as NaN instead of a recycled sequence's
    /// values; release builds skip the pass (the allocator guarantees no
    /// live table references a freed block).
    pub fn release_blocks(&mut self, blocks: &[BlockId]) {
        if cfg!(debug_assertions) {
            self.poison_blocks(blocks);
        }
    }

    /// Post-drain audit: every block the allocator reports free must be
    /// unreadable — poisoned (all-NaN, the debug free path) or never
    /// written (all zeros).  A free block holding live-looking values
    /// means a release was skipped or a stale table wrote into freed
    /// memory.  The scan needs the debug poison to discriminate, so it
    /// only runs under `cfg!(debug_assertions)` (tier-1 `cargo test` is
    /// a debug build, so CI exercises it on every engine run); release
    /// builds return Ok without reading the pool.
    pub fn audit(&self, free: &[BlockId]) -> Result<(), String> {
        if !cfg!(debug_assertions) {
            return Ok(());
        }
        let mut row = vec![0.0f32; self.d];
        for &b in free {
            if b >= self.n_blocks {
                continue; // allocated on paper, never materialized
            }
            for layer in 0..self.n_layers {
                for pb in 0..self.block_size {
                    let r = self.row_index(b, pb, layer);
                    for (side, pool) in [("K", &self.k), ("V", &self.v)] {
                        pool.read_row(r, self.d, &mut row);
                        let clean =
                            row.iter().all(|x| x.is_nan()) || row.iter().all(|&x| x == 0.0);
                        if !clean {
                            return Err(format!(
                                "free block {b} {side} row (layer {layer}, pos {pb}) holds live values"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Unconditionally poison the given blocks so every read dequantizes
    /// to NaN (test hook; the debug-build free path routes through
    /// here).  For `kv4` this is the reserved poison scale pattern —
    /// NaN scale/zero — rather than a value fill.
    pub fn poison_blocks(&mut self, blocks: &[BlockId]) {
        let rpb = self.rows_per_block();
        for &b in blocks {
            if b >= self.n_blocks {
                continue; // never written -> nothing to poison
            }
            self.k.poison_rows(b * rpb, rpb, self.d);
            self.v.poison_rows(b * rpb, rpb, self.d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(d: usize, fill: f32) -> Vec<f32> {
        vec![fill; d]
    }

    #[test]
    fn write_then_read_roundtrip_through_table() {
        let mut kv = PagedKvCache::new(4, 4, 2, 8);
        let table = [2usize, 0]; // deliberately out of order
        kv.write(&table, 1, 0, &rows(8, 1.5), &rows(8, -2.0));
        kv.write(&table, 5, 1, &rows(8, 3.0), &rows(8, 4.0));
        // pos 1 -> block table[0]=2 offset 1; pos 5 -> table[1]=0 offset 1
        assert_eq!(kv.k_row(2, 1, 0), rows(8, 1.5));
        assert_eq!(kv.v_row(2, 1, 0), rows(8, -2.0));
        assert_eq!(kv.k_row(0, 1, 1), rows(8, 3.0));
        assert_eq!(kv.v_row(0, 1, 1), rows(8, 4.0));
    }

    #[test]
    fn shared_block_is_shared_memory() {
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(4, 4, 1, 4, dtype);
            let table_a = [1usize, 2];
            let table_b = [1usize, 3]; // shares physical block 1 with a
            kv.write(&table_a, 0, 0, &rows(4, 7.0), &rows(4, 8.0));
            // Reading position 0 through b's table sees a's write —
            // exactly, at every dtype (a constant row is exactly
            // representable even at 4 bits).
            assert_eq!(kv.k_row(table_b[0], 0, 0), rows(4, 7.0), "dtype {dtype}");
        }
    }

    #[test]
    fn grows_on_demand() {
        let mut kv = PagedKvCache::new(0, 4, 1, 4);
        assert_eq!(kv.n_blocks(), 0);
        kv.write(&[5], 2, 0, &rows(4, 1.0), &rows(4, 2.0));
        assert!(kv.n_blocks() >= 6);
        assert_eq!(kv.k_row(5, 2, 0), rows(4, 1.0));
        // earlier blocks exist and are zeroed
        assert!(kv.k_row(0, 0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn poison_marks_freed_blocks_with_nan() {
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(2, 4, 2, 4, dtype);
            kv.write(&[0], 0, 0, &rows(4, 1.0), &rows(4, 1.0));
            kv.write(&[1], 0, 0, &rows(4, 2.0), &rows(4, 2.0));
            kv.poison_blocks(&[0]);
            assert!(
                kv.k_row(0, 0, 0).iter().all(|x| x.is_nan()),
                "freed block must read NaN under {dtype}"
            );
            assert!(kv.v_row(0, 0, 0).iter().all(|x| x.is_nan()));
            // other blocks untouched
            assert_eq!(kv.k_row(1, 0, 0), rows(4, 2.0));
            // ids past the pool are ignored, not a panic
            kv.poison_blocks(&[99]);
        }
    }

    #[test]
    fn spill_restore_roundtrip_across_physical_blocks() {
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(4, 2, 2, 4, dtype);
            let table = [3usize, 1];
            for pos in 0..4 {
                for layer in 0..2 {
                    let fill = (pos * 10 + layer) as f32;
                    kv.write(&table, pos, layer, &rows(4, fill), &rows(4, -fill));
                }
            }
            let spill = kv.spill_blocks(&table);
            assert_eq!(spill.dtype(), dtype);
            assert_eq!(spill.n_blocks(), 2);
            assert_eq!(spill.bytes(), dtype.block_bytes(2, 2, 4) * 2);
            // Swap-out: the old blocks are poisoned (freed), then the
            // spill is restored at *different* physical blocks.
            kv.poison_blocks(&table);
            let new_table = [0usize, 2];
            kv.restore_blocks(&new_table, &spill);
            for pos in 0..4 {
                for layer in 0..2 {
                    let fill = (pos * 10 + layer) as f32;
                    let (b, o) = (new_table[pos / 2], pos % 2);
                    // Restore moves packed bits: the round trip is exact
                    // at every dtype (constant rows quantize exactly).
                    assert_eq!(kv.k_row(b, o, layer), rows(4, fill), "{dtype} pos {pos} layer {layer}");
                    assert_eq!(kv.v_row(b, o, layer), rows(4, -fill));
                }
            }
        }
    }

    #[test]
    fn spill_restore_survives_poison_of_source() {
        // The exact engine ordering: spill first, poison after — the
        // spilled copy must be NaN-free even though the source block is
        // poisoned before the restore happens.
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(2, 4, 1, 4, dtype);
            kv.write(&[0], 1, 0, &rows(4, 5.0), &rows(4, 6.0));
            let spill = kv.spill_blocks(&[0]);
            kv.release_blocks(&[0]); // debug builds poison here
            kv.restore_blocks(&[1], &spill);
            assert!(
                kv.k_row(1, 1, 0).iter().all(|x| x.is_finite()),
                "restored K must be NaN-free under {dtype}"
            );
            assert_eq!(kv.k_row(1, 1, 0), rows(4, 5.0));
            assert_eq!(kv.v_row(1, 1, 0), rows(4, 6.0));
        }
    }

    #[test]
    fn spill_of_never_written_block_is_zeros_and_restore_grows() {
        let kv = PagedKvCache::new(1, 2, 1, 2);
        // Block 7 is past the 1-block pool: allocated on paper, never
        // written — it spills as zeros instead of panicking.
        let spill = kv.spill_blocks(&[7]);
        let mut kv2 = PagedKvCache::new(1, 2, 1, 2);
        kv2.restore_blocks(&[5], &spill); // grows the pool on demand
        assert!(kv2.n_blocks() >= 6);
        assert!(kv2.k_row(5, 0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "spill/pool dtype mismatch")]
    fn restore_rejects_mismatched_dtype() {
        let kv = PagedKvCache::with_dtype(1, 2, 1, 2, KvDtype::F16);
        let spill = kv.spill_blocks(&[0]);
        let mut f32_pool = PagedKvCache::new(1, 2, 1, 2);
        f32_pool.restore_blocks(&[0], &spill);
    }

    #[test]
    fn dtype_names_parse_and_roundtrip() {
        for dtype in KvDtype::ALL {
            assert_eq!(KvDtype::parse(dtype.name()), Some(dtype));
            assert_eq!(KvDtype::parse(&dtype.name().to_ascii_uppercase()), Some(dtype));
            assert_eq!(format!("{dtype}"), dtype.name());
        }
        assert_eq!(KvDtype::parse("int8"), None);
    }

    #[test]
    fn bytes_accounting_is_dtype_aware() {
        // d=64 rows: f32 256 B, f16 128 B (2x), kv4 40 B (6.4x) per side.
        assert_eq!(KvDtype::F32.row_bytes(64), 256);
        assert_eq!(KvDtype::F16.row_bytes(64), 128);
        assert_eq!(KvDtype::Kv4.row_bytes(64), 40);
        for dtype in KvDtype::ALL {
            let kv = PagedKvCache::with_dtype(3, 4, 2, 64, dtype);
            assert_eq!(kv.bytes(), 3 * dtype.block_bytes(4, 2, 64));
            assert_eq!(kv.bytes_per_token(), 2 * 2 * dtype.row_bytes(64));
        }
        // The compression ratios the capacity bench gates.
        let f32b = KvDtype::F32.block_bytes(16, 2, 64) as f64;
        assert!(f32b / KvDtype::F16.block_bytes(16, 2, 64) as f64 >= 1.9);
        assert!(f32b / KvDtype::Kv4.block_bytes(16, 2, 64) as f64 >= 3.5);
    }

    #[test]
    fn f16_rows_roundtrip_representable_values_exactly() {
        let mut kv = PagedKvCache::with_dtype(1, 2, 1, 4, KvDtype::F16);
        // All exactly representable in binary16.
        let vals = [1.5f32, -0.25, 1024.0, 0.0009765625];
        kv.write(&[0], 0, 0, &vals, &vals);
        assert_eq!(kv.k_row(0, 0, 0), vals.to_vec());
        // A value needing rounding lands within half an ulp.
        let fine = [0.1f32, 0.2, 0.3, 0.4];
        kv.write(&[0], 1, 0, &fine, &fine);
        for (got, want) in kv.k_row(0, 1, 0).iter().zip(&fine) {
            assert!((got - want).abs() <= want.abs() * 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn kv4_rows_quantize_within_scale_and_pin_extremes() {
        let mut kv = PagedKvCache::with_dtype(1, 2, 1, 8, KvDtype::Kv4);
        let vals = [-3.0f32, -1.0, 0.0, 0.5, 1.0, 2.0, 2.5, 3.0];
        kv.write(&[0], 0, 0, &vals, &vals);
        let got = kv.k_row(0, 0, 0);
        // Affine 4-bit: error bounded by half a step; min/max exact.
        let step = (3.0 - -3.0) / 15.0;
        for (g, w) in got.iter().zip(&vals) {
            assert!((g - w).abs() <= step / 2.0 + 1e-6, "{g} vs {w}");
        }
        assert_eq!(got[0], -3.0, "row min must be a code endpoint");
        assert_eq!(got[7], 3.0, "row max must be a code endpoint");
    }

    #[test]
    fn kv4_write_is_a_pure_function_of_the_row() {
        // Write-once purity: the same row value always stores the same
        // bits, regardless of what was in the cell before (requantize
        // history must not exist — chunked-prefill parity rides on it).
        let vals: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        let mut a = PagedKvCache::with_dtype(1, 2, 1, 8, KvDtype::Kv4);
        a.write(&[0], 0, 0, &vals, &vals);
        let mut b = PagedKvCache::with_dtype(1, 2, 1, 8, KvDtype::Kv4);
        b.write(&[0], 0, 0, &rows(8, 1e6), &rows(8, -1e6)); // unrelated prior write
        b.write(&[0], 0, 0, &vals, &vals);
        assert_eq!(a.k_row(0, 0, 0), b.k_row(0, 0, 0));
        assert_eq!(a.v_row(0, 0, 0), b.v_row(0, 0, 0));
    }

    #[test]
    fn kv4_nan_input_stores_the_poison_pattern() {
        let mut kv = PagedKvCache::with_dtype(1, 2, 1, 4, KvDtype::Kv4);
        kv.write(&[0], 0, 0, &[1.0, f32::NAN, 2.0, 3.0], &rows(4, 1.0));
        assert!(kv.k_row(0, 0, 0).iter().all(|x| x.is_nan()), "NaN rows must stay loud");
        assert_eq!(kv.v_row(0, 0, 0), rows(4, 1.0), "the clean side is unaffected");
    }

    #[test]
    fn audit_accepts_poisoned_and_virgin_blocks_only() {
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(3, 2, 1, 4, dtype);
            // Fresh pool: every block is virgin — audit is clean.
            kv.audit(&[0, 1, 2]).unwrap();
            // Ids past the pool are "allocated on paper", also clean.
            kv.audit(&[0, 1, 2, 9]).unwrap();
            kv.write(&[1], 0, 0, &rows(4, 3.0), &rows(4, 3.0));
            if cfg!(debug_assertions) {
                let err = kv.audit(&[1]).unwrap_err();
                assert!(err.contains("block 1"), "{err}");
            }
            kv.audit(&[0, 2]).unwrap();
            // The normal free path (debug poison) restores cleanliness.
            kv.release_blocks(&[1]);
            kv.audit(&[0, 1, 2]).unwrap();
        }
    }

    #[test]
    fn block_tiles_match_row_reads() {
        for dtype in KvDtype::ALL {
            let mut kv = PagedKvCache::with_dtype(2, 4, 2, 8, KvDtype::F32);
            let mut qkv = PagedKvCache::with_dtype(2, 4, 2, 8, dtype);
            for pos in 0..8 {
                for layer in 0..2 {
                    let row: Vec<f32> =
                        (0..8).map(|c| ((pos * 31 + layer * 7 + c) as f32 * 0.37).sin()).collect();
                    kv.write(&[0, 1], pos, layer, &row, &row);
                    qkv.write(&[0, 1], pos, layer, &row, &row);
                }
            }
            let mut scratch = vec![0.0; qkv.tile_len()];
            for blk in 0..2 {
                for layer in 0..2 {
                    let tile = qkv.k_block(blk, layer, &mut scratch).to_vec();
                    for pb in 0..4 {
                        assert_eq!(
                            &tile[pb * 8..pb * 8 + 8],
                            &qkv.k_row(blk, pb, layer)[..],
                            "{dtype}: tile and row reads must agree (blk {blk} layer {layer} pb {pb})"
                        );
                    }
                    let vtile = qkv.v_block(blk, layer, &mut scratch).to_vec();
                    assert_eq!(&vtile[..8], &qkv.v_row(blk, 0, layer)[..]);
                }
            }
        }
    }
}

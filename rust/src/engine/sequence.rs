//! Per-sequence state tracked by the scheduler.

use super::request::{Request, SamplingParams};

/// Lifecycle of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    /// In the waiting queue (not yet prefilling).
    Waiting,
    /// Admitted: KV allocated, prompt not yet run.
    Prefilling,
    /// In the decode batch.
    Running,
    /// Evicted under memory pressure; will re-prefill from scratch.
    Preempted,
    Finished,
}

/// A request plus its generation state.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub sampling: SamplingParams,
    pub state: SeqState,
    pub arrival: f64,
    pub first_token_time: Option<f64>,
    pub finish_time: Option<f64>,
    pub preemptions: usize,
}

impl Sequence {
    pub fn new(req: &Request) -> Sequence {
        Sequence {
            id: req.id,
            prompt: req.prompt.clone(),
            generated: Vec::new(),
            sampling: req.sampling,
            state: SeqState::Waiting,
            arrival: req.arrival,
            first_token_time: None,
            finish_time: None,
            preemptions: 0,
        }
    }

    /// Total tokens currently materialized in the KV cache.
    pub fn total_tokens(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// The token fed to the next decode step.
    pub fn last_token(&self) -> u32 {
        *self
            .generated
            .last()
            .or_else(|| self.prompt.last())
            .expect("sequence cannot be empty")
    }

    /// Context length (position of the next token).
    pub fn position(&self) -> usize {
        self.total_tokens()
    }

    pub fn is_done(&self, max_seq_len: usize) -> Option<super::request::FinishReason> {
        use super::request::FinishReason;
        if let Some(stop) = self.sampling.stop_token {
            if self.generated.last() == Some(&stop) {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.sampling.max_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if self.total_tokens() >= max_seq_len {
            return Some(FinishReason::LengthCap);
        }
        None
    }

    /// Reset for recompute after preemption: generated tokens are kept
    /// (they are re-prefilled as part of the new prompt pass).
    pub fn preempt(&mut self) {
        self.state = SeqState::Preempted;
        self.preemptions += 1;
    }

    /// The effective prompt for (re-)prefill: original prompt plus
    /// whatever was already generated before preemption.
    pub fn effective_prompt(&self) -> Vec<u32> {
        let mut p = self.prompt.clone();
        p.extend_from_slice(&self.generated);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::{FinishReason, Request};

    fn seq(max_tokens: usize) -> Sequence {
        let req = Request::new(
            0,
            vec![1, 2, 3],
            SamplingParams { max_tokens, ..Default::default() },
        );
        Sequence::new(&req)
    }

    #[test]
    fn lifecycle_counters() {
        let mut s = seq(4);
        assert_eq!(s.total_tokens(), 3);
        assert_eq!(s.last_token(), 3);
        s.generated.push(9);
        assert_eq!(s.total_tokens(), 4);
        assert_eq!(s.last_token(), 9);
        assert_eq!(s.position(), 4);
    }

    #[test]
    fn finishes_at_max_tokens() {
        let mut s = seq(2);
        assert!(s.is_done(100).is_none());
        s.generated.extend([5, 6]);
        assert_eq!(s.is_done(100), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn finishes_at_stop_token() {
        let mut s = seq(10);
        s.sampling.stop_token = Some(0);
        s.generated.push(7);
        assert!(s.is_done(100).is_none());
        s.generated.push(0);
        assert_eq!(s.is_done(100), Some(FinishReason::StopToken));
    }

    #[test]
    fn finishes_at_length_cap() {
        let mut s = seq(100);
        s.generated.extend([1, 2, 3, 4, 5]);
        assert_eq!(s.is_done(8), Some(FinishReason::LengthCap));
    }

    #[test]
    fn preemption_preserves_generated_tokens() {
        let mut s = seq(10);
        s.generated.extend([4, 5]);
        s.preempt();
        assert_eq!(s.state, SeqState::Preempted);
        assert_eq!(s.effective_prompt(), vec![1, 2, 3, 4, 5]);
        assert_eq!(s.preemptions, 1);
    }
}

//! Per-sequence state tracked by the scheduler.

use super::request::{Request, SamplingParams};

/// Lifecycle of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    /// In the waiting queue (not yet prefilling).
    Waiting,
    /// Admitted: KV allocated, prompt running in block-aligned chunks
    /// across one or more engine steps ([`Sequence::prefill_pos`] tracks
    /// progress; the cached prefix is skipped outright).
    Prefilling,
    /// In the decode batch.
    Running,
    /// Evicted under memory pressure; will re-prefill from scratch.
    Preempted,
    /// Evicted under memory pressure with its K/V spilled to the
    /// backend's host-side pool; [`Sequence::prefill_pos`] still counts
    /// the materialized span, so the resume recomputes nothing — a
    /// swap-in restores the spill and continues exactly where the
    /// sequence stopped (mid-prefill: the remaining chunks; mid-decode:
    /// a single-token final chunk feeding the last sampled token).
    Swapped,
    Finished,
}

impl SeqState {
    /// Stable on-disk tag (checkpoint record format; never reorder —
    /// snapshots persist these values).
    pub fn to_tag(self) -> u8 {
        match self {
            SeqState::Waiting => 0,
            SeqState::Prefilling => 1,
            SeqState::Running => 2,
            SeqState::Preempted => 3,
            SeqState::Swapped => 4,
            SeqState::Finished => 5,
        }
    }

    pub fn from_tag(tag: u8) -> Option<SeqState> {
        Some(match tag {
            0 => SeqState::Waiting,
            1 => SeqState::Prefilling,
            2 => SeqState::Running,
            3 => SeqState::Preempted,
            4 => SeqState::Swapped,
            5 => SeqState::Finished,
            _ => return None,
        })
    }
}

/// A request plus its generation state.
#[derive(Debug, Clone, PartialEq)]
pub struct Sequence {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub sampling: SamplingParams,
    pub state: SeqState,
    pub arrival: f64,
    /// Request priority (higher = served first); ties fall back to FCFS.
    pub priority: i32,
    /// Absolute deadline on the engine clock (None = unbounded); checked
    /// by the engine every step, in every state.
    pub deadline: Option<f64>,
    /// Virtual-clock time of the *first* admission (None while still
    /// queued): `admitted_time - arrival` is the request's queue time.
    pub admitted_time: Option<f64>,
    pub first_token_time: Option<f64>,
    pub finish_time: Option<f64>,
    pub preemptions: usize,
    /// Leading prompt tokens skipped at admission because their K/V
    /// already lived in fully-computed shared prefix blocks (this
    /// admission only; reset by preemption).
    pub cached_len: usize,
    /// Prefill progress: prompt tokens already materialized in (or
    /// skipped into) the KV cache.  Starts at `cached_len` on admission;
    /// prefill is complete when it reaches the effective prompt length.
    pub prefill_pos: usize,
}

impl Sequence {
    pub fn new(req: &Request) -> Sequence {
        Sequence {
            id: req.id,
            prompt: req.prompt.clone(),
            generated: Vec::new(),
            sampling: req.sampling,
            state: SeqState::Waiting,
            arrival: req.arrival,
            priority: req.priority,
            deadline: req.deadline,
            admitted_time: None,
            first_token_time: None,
            finish_time: None,
            preemptions: 0,
            cached_len: 0,
            prefill_pos: 0,
        }
    }

    /// Total tokens currently materialized in the KV cache.
    pub fn total_tokens(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// The token fed to the next decode step.
    pub fn last_token(&self) -> u32 {
        *self
            .generated
            .last()
            .or_else(|| self.prompt.last())
            .expect("sequence cannot be empty")
    }

    /// Context length (position of the next token).
    pub fn position(&self) -> usize {
        self.total_tokens()
    }

    pub fn is_done(&self, max_seq_len: usize) -> Option<super::request::FinishReason> {
        use super::request::FinishReason;
        if let Some(stop) = self.sampling.stop_token {
            if self.generated.last() == Some(&stop) {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.sampling.max_tokens {
            return Some(FinishReason::MaxTokens);
        }
        if self.total_tokens() >= max_seq_len {
            return Some(FinishReason::LengthCap);
        }
        None
    }

    /// Remaining un-prefilled prompt tokens (0 once prefill completed).
    pub fn prefill_remaining(&self) -> usize {
        self.total_tokens().saturating_sub(self.prefill_pos)
    }

    /// Reset for recompute after preemption: generated tokens are kept
    /// (they are re-prefilled as part of the new prompt pass), but all
    /// prefill progress is discarded — the blocks are gone, and the next
    /// admission recomputes `cached_len` against the then-current cache.
    pub fn preempt(&mut self) {
        self.state = SeqState::Preempted;
        self.preemptions += 1;
        self.cached_len = 0;
        self.prefill_pos = 0;
    }

    /// Evict with K/V preserved: the blocks move to the backend's spill
    /// pool, so prefill progress is *kept* — the resumed sequence never
    /// recomputes the swapped span.  A mid-prefill victim keeps its
    /// chunk cursor as-is; a decode-phase victim has everything but its
    /// last sampled token materialized, so the cursor lands one short of
    /// the total and the resume is a single-token final chunk (which
    /// re-samples through the same per-request RNG stream a decode step
    /// would have used — bit-identical replay).  `cached_len` survives
    /// too: the skipped prefix was materialized before the swap, and
    /// `prefill_pos >= cached_len` still holds since the cursor only
    /// ever grew from `cached_len`.
    pub fn swap_out(&mut self) {
        debug_assert!(matches!(self.state, SeqState::Prefilling | SeqState::Running));
        if self.state == SeqState::Running {
            self.prefill_pos = self.total_tokens() - 1;
        }
        self.state = SeqState::Swapped;
        self.preemptions += 1;
    }

    /// Convert an in-flight swap into a recompute: the spill write or
    /// restore failed, so the materialized span is unrecoverable —
    /// reset the prefill cursors exactly like a recompute preemption,
    /// but without counting a second preemption (the original eviction
    /// already did).  Generated tokens are kept and replayed through
    /// the same RNG stream, so completed tokens stay bit-identical.
    pub fn demote_to_recompute(&mut self) {
        self.state = SeqState::Preempted;
        self.cached_len = 0;
        self.prefill_pos = 0;
    }

    /// The effective prompt for (re-)prefill: original prompt plus
    /// whatever was already generated before preemption.
    pub fn effective_prompt(&self) -> Vec<u32> {
        let mut p = self.prompt.clone();
        p.extend_from_slice(&self.generated);
        p
    }

    /// One span of the effective prompt, materialized without cloning
    /// the rest: the engine builds each prefill chunk's token buffer
    /// through this, so a long prompt chunked at budget B copies O(L)
    /// tokens total instead of O(L²/B) whole-prompt clones.
    pub fn effective_slice(&self, start: usize, len: usize) -> Vec<u32> {
        let end = start + len;
        debug_assert!(end <= self.total_tokens());
        let p = self.prompt.len();
        let mut out = Vec::with_capacity(len);
        if start < p {
            out.extend_from_slice(&self.prompt[start..end.min(p)]);
        }
        if end > p {
            out.extend_from_slice(&self.generated[start.max(p) - p..end - p]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::{FinishReason, Request};

    fn seq(max_tokens: usize) -> Sequence {
        let req = Request::new(
            0,
            vec![1, 2, 3],
            SamplingParams { max_tokens, ..Default::default() },
        );
        Sequence::new(&req)
    }

    #[test]
    fn lifecycle_counters() {
        let mut s = seq(4);
        assert_eq!(s.total_tokens(), 3);
        assert_eq!(s.last_token(), 3);
        s.generated.push(9);
        assert_eq!(s.total_tokens(), 4);
        assert_eq!(s.last_token(), 9);
        assert_eq!(s.position(), 4);
    }

    #[test]
    fn finishes_at_max_tokens() {
        let mut s = seq(2);
        assert!(s.is_done(100).is_none());
        s.generated.extend([5, 6]);
        assert_eq!(s.is_done(100), Some(FinishReason::MaxTokens));
    }

    #[test]
    fn finishes_at_stop_token() {
        let mut s = seq(10);
        s.sampling.stop_token = Some(0);
        s.generated.push(7);
        assert!(s.is_done(100).is_none());
        s.generated.push(0);
        assert_eq!(s.is_done(100), Some(FinishReason::StopToken));
    }

    #[test]
    fn finishes_at_length_cap() {
        let mut s = seq(100);
        s.generated.extend([1, 2, 3, 4, 5]);
        assert_eq!(s.is_done(8), Some(FinishReason::LengthCap));
    }

    #[test]
    fn preemption_preserves_generated_tokens() {
        let mut s = seq(10);
        s.generated.extend([4, 5]);
        s.cached_len = 2;
        s.prefill_pos = 5;
        s.preempt();
        assert_eq!(s.state, SeqState::Preempted);
        assert_eq!(s.effective_prompt(), vec![1, 2, 3, 4, 5]);
        assert_eq!(s.preemptions, 1);
        assert_eq!((s.cached_len, s.prefill_pos), (0, 0), "prefill progress must reset");
    }

    #[test]
    fn swap_out_keeps_prefill_progress() {
        // Mid-prefill victim: the cursor freezes where it was.
        let mut s = seq(10); // prompt [1, 2, 3]
        s.state = SeqState::Prefilling;
        s.cached_len = 1;
        s.prefill_pos = 2;
        s.swap_out();
        assert_eq!(s.state, SeqState::Swapped);
        assert_eq!((s.cached_len, s.prefill_pos), (1, 2), "swap must not reset progress");
        assert_eq!(s.preemptions, 1);

        // Decode-phase victim: everything but the last sampled token is
        // materialized — the resume is a 1-token final chunk.
        let mut s = seq(10);
        s.generated.extend([4, 5]);
        s.state = SeqState::Running;
        s.prefill_pos = 3;
        s.swap_out();
        assert_eq!(s.prefill_pos, 4, "one short of total_tokens (5)");
        assert_eq!(s.prefill_remaining(), 1);
    }

    #[test]
    fn effective_slice_matches_effective_prompt() {
        let mut s = seq(10); // prompt [1, 2, 3]
        s.generated.extend([4, 5, 6]);
        let full = s.effective_prompt();
        for start in 0..full.len() {
            for len in 0..=full.len() - start {
                assert_eq!(
                    s.effective_slice(start, len),
                    full[start..start + len].to_vec(),
                    "start {start} len {len}"
                );
            }
        }
    }

    #[test]
    fn state_tags_roundtrip() {
        for st in [
            SeqState::Waiting,
            SeqState::Prefilling,
            SeqState::Running,
            SeqState::Preempted,
            SeqState::Swapped,
            SeqState::Finished,
        ] {
            assert_eq!(SeqState::from_tag(st.to_tag()), Some(st));
        }
        assert_eq!(SeqState::from_tag(99), None);
    }

    #[test]
    fn prefill_progress_tracking() {
        let mut s = seq(10); // 3-token prompt
        assert_eq!(s.prefill_remaining(), 3);
        s.prefill_pos = 2;
        assert_eq!(s.prefill_remaining(), 1);
        s.prefill_pos = 3;
        assert_eq!(s.prefill_remaining(), 0);
    }
}

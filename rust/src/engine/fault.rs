//! Deterministic, seeded fault injection at the engine↔backend seams.
//!
//! A [`FaultPlan`] names a seed and a per-seam probability; a
//! [`FaultSchedule`] turns it into replayable injection decisions: each
//! seam keeps a draw counter, and draw `i` at seam `s` is a pure
//! function of `(seed, s, i)` — re-running the same engine
//! configuration over the same workload replays the exact same faults,
//! independent of wall time and of every other RNG stream in the
//! process (request sampling streams are never touched, which is what
//! keeps completed-request tokens bit-identical to a fault-free run).
//!
//! The seams (see the table in `engine/mod.rs`):
//!
//! | seam                | injects                                    | recovery                         |
//! |---------------------|--------------------------------------------|----------------------------------|
//! | `StepTransient`     | `Backend::step` fails retryably            | bounded backoff + preempt/retry  |
//! | `StepPermanent`     | `Backend::step` fails terminally           | batch resolves `Failed`          |
//! | `SpillOut`          | swap-out spill write fails                 | demote to discard-and-recompute  |
//! | `SpillIn`           | swap-in restore fails                      | drop spill, recompute from zero  |
//! | `Alloc`             | block allocation / append refused          | defer admission / preempt self   |
//! | `MidLayerPoison`    | NaN-poisons one attention tile *inside* `CpuBackend::step` | non-finite logits surface as a terminal step error |
//! | `CrashBeforeCommit` | process death at a checkpoint boundary, **before** the snapshot commits | `Engine::restore` from the previous snapshot |
//! | `CrashAfterCommit`  | process death **after** the snapshot commits | `Engine::restore` from the just-committed snapshot |
//!
//! Faults are injected *engine-side*, before the backend call they
//! model would run, so backend state (the paged pool, the spill map,
//! the virtual clock) is never half-mutated by a failed operation.
//! `MidLayerPoison` is the deliberate exception: it corrupts state
//! *inside* the backend pass to prove the detection layers (the
//! non-finite logit check, parity tests, the post-drain auditor) catch
//! in-flight corruption loudly.  The crash seams model process death at
//! the checkpoint boundary — kill-point testing for `engine::persist`.
//!
//! The default plan comes from `OPT4GPTQ_FAULTS` (resolved through
//! [`crate::envcfg`], warn-once like every other override) with spec
//! syntax `seed=42,step=0.05,step_perm=0.01,spill_out=0.1,spill_in=0.1,alloc=0.05,poison=0.01,crash_before=0.01,crash_after=0.01`
//! — every key optional, unknown keys rejected.

use std::sync::OnceLock;

use crate::envcfg::{self, EnvOverride};
use crate::rng::Rng;

/// One engine↔backend seam a fault can fire at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSeam {
    /// `Backend::step` returns a retryable [`StepError::Transient`](super::StepError).
    StepTransient,
    /// `Backend::step` returns a terminal [`StepError::Permanent`](super::StepError).
    StepPermanent,
    /// A swap-out spill write fails before any payload moves.
    SpillOut,
    /// A swap-in restore fails before any payload moves.
    SpillIn,
    /// A block allocation (admission headroom or decode append) is refused.
    Alloc,
    /// One attention tile inside `CpuBackend::step` is NaN-poisoned
    /// mid-layer (corruption *inside* the backend pass, not at a seam).
    MidLayerPoison,
    /// The process dies at a checkpoint boundary **before** the snapshot
    /// commits (the atomic rename never happens).
    CrashBeforeCommit,
    /// The process dies **after** the snapshot commits (restore resumes
    /// from the state just persisted).
    CrashAfterCommit,
}

/// Number of fault seams (the draw/fired array width a checkpoint
/// persists).
pub const N_SEAMS: usize = 8;

impl FaultSeam {
    pub const ALL: [FaultSeam; N_SEAMS] = [
        FaultSeam::StepTransient,
        FaultSeam::StepPermanent,
        FaultSeam::SpillOut,
        FaultSeam::SpillIn,
        FaultSeam::Alloc,
        FaultSeam::MidLayerPoison,
        FaultSeam::CrashBeforeCommit,
        FaultSeam::CrashAfterCommit,
    ];

    fn index(self) -> usize {
        match self {
            FaultSeam::StepTransient => 0,
            FaultSeam::StepPermanent => 1,
            FaultSeam::SpillOut => 2,
            FaultSeam::SpillIn => 3,
            FaultSeam::Alloc => 4,
            FaultSeam::MidLayerPoison => 5,
            FaultSeam::CrashBeforeCommit => 6,
            FaultSeam::CrashAfterCommit => 7,
        }
    }

    /// Per-seam salt so the decision streams are independent even under
    /// one seed.
    fn salt(self) -> u64 {
        [
            0x7374_6570_5f74_7261, // "step_tra"
            0x7374_6570_5f70_6572, // "step_per"
            0x7370_696c_6c5f_6f75, // "spill_ou"
            0x7370_696c_6c5f_696e, // "spill_in"
            0x616c_6c6f_635f_5f5f, // "alloc___"
            0x706f_6973_6f6e_5f5f, // "poison__"
            0x6372_6173_685f_6263, // "crash_bc"
            0x6372_6173_685f_6163, // "crash_ac"
        ][self.index()]
    }

    /// The spec key naming this seam in `OPT4GPTQ_FAULTS`.
    pub fn spec_key(self) -> &'static str {
        [
            "step",
            "step_perm",
            "spill_out",
            "spill_in",
            "alloc",
            "poison",
            "crash_before",
            "crash_after",
        ][self.index()]
    }
}

/// A seeded fault-injection configuration: probabilities per seam.
/// `Copy` so it rides inside [`EngineConfig`](super::EngineConfig).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Seed for the injection decision streams (independent of every
    /// sampling RNG).
    pub seed: u64,
    /// P(transient `step()` failure) per engine step.
    pub step_transient: f64,
    /// P(permanent `step()` failure) per engine step.
    pub step_permanent: f64,
    /// P(spill write failure) per swapped-out sequence.
    pub spill_out: f64,
    /// P(restore failure) per swapped-in sequence.
    pub spill_in: f64,
    /// P(allocation refusal) per admission/append allocation.
    pub alloc: f64,
    /// P(one attention tile NaN-poisoned inside the backend pass) per
    /// engine step.
    pub mid_layer_poison: f64,
    /// P(process death before a checkpoint commits) per checkpoint.
    pub crash_before: f64,
    /// P(process death after a checkpoint commits) per checkpoint.
    pub crash_after: f64,
}

impl FaultPlan {
    /// No faults: every seam at probability zero.
    pub const NONE: FaultPlan = FaultPlan {
        seed: 0,
        step_transient: 0.0,
        step_permanent: 0.0,
        spill_out: 0.0,
        spill_in: 0.0,
        alloc: 0.0,
        mid_layer_poison: 0.0,
        crash_before: 0.0,
        crash_after: 0.0,
    };

    fn probability(&self, seam: FaultSeam) -> f64 {
        match seam {
            FaultSeam::StepTransient => self.step_transient,
            FaultSeam::StepPermanent => self.step_permanent,
            FaultSeam::SpillOut => self.spill_out,
            FaultSeam::SpillIn => self.spill_in,
            FaultSeam::Alloc => self.alloc,
            FaultSeam::MidLayerPoison => self.mid_layer_poison,
            FaultSeam::CrashBeforeCommit => self.crash_before,
            FaultSeam::CrashAfterCommit => self.crash_after,
        }
    }

    /// True when no seam can ever fire.
    pub fn is_none(&self) -> bool {
        FaultSeam::ALL.iter().all(|&s| self.probability(s) <= 0.0)
    }

    /// Parse the `OPT4GPTQ_FAULTS` spec:
    /// `seed=42,step=0.05,step_perm=0.01,spill_out=0.1,spill_in=0.1,alloc=0.05`.
    /// Every key is optional (missing seams stay at 0.0, missing seed
    /// stays 0); unknown keys, non-numeric values and probabilities
    /// outside `[0, 1]` are rejected.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::NONE;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                return Err(format!("fault spec item {part:?} is not key=value"));
            };
            let (key, value) = (key.trim(), value.trim());
            if key == "seed" {
                plan.seed = value
                    .parse()
                    .map_err(|_| format!("fault spec seed {value:?} is not a u64"))?;
                continue;
            }
            let p: f64 = value
                .parse()
                .map_err(|_| format!("fault spec {key}={value:?} is not a probability"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("fault spec {key}={p} is outside [0, 1]"));
            }
            match key {
                "step" => plan.step_transient = p,
                "step_perm" => plan.step_permanent = p,
                "spill_out" => plan.spill_out = p,
                "spill_in" => plan.spill_in = p,
                "alloc" => plan.alloc = p,
                "poison" => plan.mid_layer_poison = p,
                "crash_before" => plan.crash_before = p,
                "crash_after" => plan.crash_after = p,
                other => {
                    return Err(format!(
                        "unknown fault spec key {other:?} (valid: seed, step, step_perm, \
                         spill_out, spill_in, alloc, poison, crash_before, crash_after)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::NONE
    }
}

static FAULTS_ENV: OnceLock<EnvOverride<FaultPlan>> = OnceLock::new();

/// The process-default fault plan: `OPT4GPTQ_FAULTS` when set and valid
/// (warn-once fallback otherwise), [`FaultPlan::NONE`] when absent.
/// Feeds `EngineConfig::default()`; explicit configs override it.
pub fn fault_plan_default() -> FaultPlan {
    envcfg::env_override(&FAULTS_ENV, "OPT4GPTQ_FAULTS", |raw| {
        FaultPlan::parse(raw)
            .map_err(|e| format!("ignoring OPT4GPTQ_FAULTS: {e}; running fault-free"))
    })
    .value()
    .copied()
    .unwrap_or(FaultPlan::NONE)
}

/// The live injection schedule: a plan plus per-seam draw counters.
#[derive(Debug, Clone)]
pub struct FaultSchedule {
    plan: FaultPlan,
    draws: [u64; N_SEAMS],
    fired: [u64; N_SEAMS],
}

impl FaultSchedule {
    /// A schedule that never fires (the unit-test default).
    pub fn none() -> FaultSchedule {
        FaultSchedule::new(FaultPlan::NONE)
    }

    pub fn new(plan: FaultPlan) -> FaultSchedule {
        FaultSchedule { plan, draws: [0; N_SEAMS], fired: [0; N_SEAMS] }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// True when no seam can ever fire (the fast path skips the draw
    /// bookkeeping entirely).
    pub fn is_none(&self) -> bool {
        self.plan.is_none()
    }

    /// Decide whether draw `i` at `seam` fires, advancing the seam's
    /// counter.  Pure in `(seed, seam, i)`: replays are bit-identical.
    pub fn fire(&mut self, seam: FaultSeam) -> bool {
        let p = self.plan.probability(seam);
        if self.plan.is_none() {
            return false;
        }
        let i = self.draws[seam.index()];
        self.draws[seam.index()] += 1;
        if p <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(
            self.plan
                .seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(seam.salt())
                ^ i.wrapping_mul(0xA24B_AED4_963E_E407),
        );
        let fires = rng.f64() < p;
        if fires {
            self.fired[seam.index()] += 1;
        }
        fires
    }

    /// How many times `seam` has fired so far (test/metrics hook).
    pub fn fired(&self, seam: FaultSeam) -> u64 {
        self.fired[seam.index()]
    }

    /// Total faults fired across all seams.
    pub fn total_fired(&self) -> u64 {
        self.fired.iter().sum()
    }

    /// The per-seam (draws, fired) counters — persisted by checkpoints
    /// so a restored engine continues the exact same decision streams
    /// (draw `i` at a seam is pure in `(seed, seam, i)`, so replay only
    /// needs `i` back).
    pub fn draw_state(&self) -> ([u64; N_SEAMS], [u64; N_SEAMS]) {
        (self.draws, self.fired)
    }

    /// Restore persisted [`Self::draw_state`] counters.
    pub fn set_draw_state(&mut self, draws: [u64; N_SEAMS], fired: [u64; N_SEAMS]) {
        self.draws = draws;
        self.fired = fired;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let mut s = FaultSchedule::none();
        assert!(s.is_none());
        for _ in 0..1000 {
            for seam in FaultSeam::ALL {
                assert!(!s.fire(seam));
            }
        }
        assert_eq!(s.total_fired(), 0);
    }

    #[test]
    fn draws_are_replayable() {
        let plan = FaultPlan { seed: 0xfa17, step_transient: 0.3, alloc: 0.5, ..FaultPlan::NONE };
        let mut a = FaultSchedule::new(plan);
        let mut b = FaultSchedule::new(plan);
        for i in 0..500 {
            for seam in FaultSeam::ALL {
                assert_eq!(a.fire(seam), b.fire(seam), "draw {i} at {seam:?} diverged");
            }
        }
        assert_eq!(a.fired(FaultSeam::StepTransient), b.fired(FaultSeam::StepTransient));
    }

    #[test]
    fn fire_rate_tracks_probability() {
        let plan = FaultPlan { seed: 7, step_transient: 0.25, ..FaultPlan::NONE };
        let mut s = FaultSchedule::new(plan);
        let n = 20_000;
        for _ in 0..n {
            s.fire(FaultSeam::StepTransient);
        }
        let rate = s.fired(FaultSeam::StepTransient) as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
        // Zero-probability seams never fire even while others draw.
        assert_eq!(s.fired(FaultSeam::Alloc), 0);
    }

    #[test]
    fn seams_draw_independent_streams() {
        let plan = FaultPlan {
            seed: 11,
            step_transient: 0.5,
            spill_out: 0.5,
            ..FaultPlan::NONE
        };
        let mut s = FaultSchedule::new(plan);
        let a: Vec<bool> = (0..64).map(|_| s.fire(FaultSeam::StepTransient)).collect();
        let b: Vec<bool> = (0..64).map(|_| s.fire(FaultSeam::SpillOut)).collect();
        assert_ne!(a, b, "same-seed seams must not mirror each other");
    }

    #[test]
    fn spec_parses_every_key() {
        let p = FaultPlan::parse(
            "seed=42, step=0.05, step_perm=0.01, spill_out=0.1, spill_in=0.2, alloc=0.3, \
             poison=0.4, crash_before=0.5, crash_after=0.6",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.step_transient, 0.05);
        assert_eq!(p.step_permanent, 0.01);
        assert_eq!(p.spill_out, 0.1);
        assert_eq!(p.spill_in, 0.2);
        assert_eq!(p.alloc, 0.3);
        assert_eq!(p.mid_layer_poison, 0.4);
        assert_eq!(p.crash_before, 0.5);
        assert_eq!(p.crash_after, 0.6);
        assert!(!p.is_none());
        for seam in FaultSeam::ALL {
            assert!(
                p.probability(seam) > 0.0,
                "{seam:?} (key {:?}) did not get a probability",
                seam.spec_key()
            );
        }
    }

    #[test]
    fn draw_state_roundtrip_resumes_the_stream() {
        // A schedule rebuilt from persisted counters must make the exact
        // decisions the original would have made next — the property a
        // crash/restore cycle needs for bit-identical fault replay.
        let plan = FaultPlan { seed: 0xc4a5, step_transient: 0.4, spill_in: 0.3, ..FaultPlan::NONE };
        let mut live = FaultSchedule::new(plan);
        for _ in 0..137 {
            live.fire(FaultSeam::StepTransient);
            live.fire(FaultSeam::SpillIn);
        }
        let (draws, fired) = live.draw_state();
        let mut restored = FaultSchedule::new(plan);
        restored.set_draw_state(draws, fired);
        for i in 0..200 {
            for seam in FaultSeam::ALL {
                assert_eq!(live.fire(seam), restored.fire(seam), "draw {i} at {seam:?}");
            }
        }
        assert_eq!(live.draw_state(), restored.draw_state());
    }

    #[test]
    fn spec_defaults_missing_keys_to_zero() {
        let p = FaultPlan::parse("step=0.5").unwrap();
        assert_eq!(p.seed, 0);
        assert_eq!(p.step_permanent, 0.0);
        assert!(!p.is_none());
        assert!(FaultPlan::parse("").unwrap().is_none());
    }

    #[test]
    fn spec_rejects_junk() {
        assert!(FaultPlan::parse("bogus=0.5").is_err());
        assert!(FaultPlan::parse("step").is_err());
        assert!(FaultPlan::parse("step=nan-ish").is_err());
        assert!(FaultPlan::parse("step=1.5").is_err());
        assert!(FaultPlan::parse("step=-0.1").is_err());
        assert!(FaultPlan::parse("seed=notanumber").is_err());
    }
}

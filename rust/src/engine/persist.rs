//! Crash-consistent on-disk engine snapshots.
//!
//! Binary sibling of the line-based [`crate::runtime::manifest`]: the
//! same commit discipline (write everything, verify on read, atomic
//! rename), but length-prefixed CRC records instead of text lines,
//! because the payload includes packed K/V block contents.
//!
//! ## File format
//!
//! ```text
//! magic    "O4GSNAP1"                      (8 bytes)
//! version  u32 LE                          (currently 2: v2 added the
//!                                           model config to TAG_CONFIG)
//! records  [len: u32 LE][crc32: u32 LE][payload: len bytes]*
//! ```
//!
//! Every record's payload starts with a one-byte type tag:
//!
//! | tag | record     | contents                                                   |
//! |-----|------------|------------------------------------------------------------|
//! | 1   | `CONFIG`   | geometry fingerprint (restore refuses a mismatched engine) |
//! | 2   | `META`     | clock, retry/stall streaks                                 |
//! | 3   | `SEQ`      | one [`Sequence`] + its sampler RNG state (one per seq)     |
//! | 4   | `PENDING`  | one not-yet-arrived [`Request`] + RNG state                |
//! | 5   | `QUEUES`   | waiting/running/prefilling membership, exact order         |
//! | 6   | `SCHED`    | scheduler counters + fault-schedule draw state             |
//! | 7   | `BLOCKS`   | full [`BlockManagerState`] (refcounts, free order, prefix index, tables, swaps) |
//! | 8   | `OUTCOMES` | resolved `(id, RequestOutcome)` pairs, resolution order    |
//! | 9   | `OUTPUTS`  | completed [`RequestOutput`]s                               |
//! | 10  | `METRICS`  | the whole [`Metrics`] struct                               |
//! | 11  | `KV`       | live block ids + their **packed** pool payload ([`KvSpill`]) |
//! | 12  | `SPILL`    | one swapped-out sequence's host-side spill (one per seq)   |
//! | 13  | `END`      | commit marker — a file without it is torn, even at a record boundary |
//!
//! A torn write (truncated tail, flipped byte) fails the length bound,
//! the CRC, or the missing-`END` check; [`load_latest`] then falls back
//! to the newest older snapshot that parses clean.  Snapshot files are
//! numbered `snap-NNNNNN.bin`, written as `.tmp` + fsync + atomic
//! rename, and pruned to the last [`KEEP_SNAPSHOTS`].
//!
//! The payload is engine-complete: [`crate::engine::Engine::restore`]
//! resumes mid-prompt and mid-decode bit-identically, and a *fresh*
//! `serve --restore` run rehydrates computed shared-prefix blocks so
//! new requests over the same system prompt skip their cached span
//! without re-prefilling (cross-run prefix persistence).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use super::block_manager::{BlockId, BlockManagerState};
use super::fault::N_SEAMS;
use super::kv::{KvDtype, KvSpill, SpillSide};
use super::metrics::Metrics;
use super::request::{FinishReason, Request, RequestOutcome, RequestOutput, SamplingParams};
use super::sequence::{SeqState, Sequence};
use super::EngineConfig;

const MAGIC: &[u8; 8] = b"O4GSNAP1";
const VERSION: u32 = 2;
/// Snapshots retained after a successful commit (older ones pruned).
pub const KEEP_SNAPSHOTS: usize = 4;

const TAG_CONFIG: u8 = 1;
const TAG_META: u8 = 2;
const TAG_SEQ: u8 = 3;
const TAG_PENDING: u8 = 4;
const TAG_QUEUES: u8 = 5;
const TAG_SCHED: u8 = 6;
const TAG_BLOCKS: u8 = 7;
const TAG_OUTCOMES: u8 = 8;
const TAG_OUTPUTS: u8 = 9;
const TAG_METRICS: u8 = 10;
const TAG_KV: u8 = 11;
const TAG_SPILL: u8 = 12;
const TAG_END: u8 = 13;

/// CRC-32 (IEEE 802.3, reflected) — in-crate, bitwise; snapshot records
/// are small enough that a table is not worth the bytes.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Geometry the restoring engine must match exactly: block tables,
/// free-list replay and packed payloads are only meaningful against the
/// same pool shape.  The fault plan is deliberately **not** part of the
/// fingerprint — a restored run typically uses a crash-free plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConfigFingerprint {
    /// Full model shape (registry name + dims + RoPE + weight seed):
    /// KV rows are `kv_dim`-wide and logits are seed-derived, so a
    /// snapshot is only replayable against the exact same model.
    pub model: crate::models::ModelConfig,
    pub max_batch: usize,
    pub block_size: usize,
    pub total_blocks: usize,
    pub max_seq_len: usize,
    pub prefill_budget: usize,
    pub prefix_skip: bool,
    pub swap_preempt: bool,
    pub kv_dtype: KvDtype,
    pub max_waiting: usize,
}

impl ConfigFingerprint {
    pub fn of(cfg: &EngineConfig) -> ConfigFingerprint {
        ConfigFingerprint {
            model: cfg.model,
            max_batch: cfg.max_batch,
            block_size: cfg.block_size,
            total_blocks: cfg.total_blocks,
            max_seq_len: cfg.max_seq_len,
            prefill_budget: cfg.prefill_budget,
            prefix_skip: cfg.prefix_skip,
            swap_preempt: cfg.swap_preempt,
            kv_dtype: cfg.kv_dtype,
            max_waiting: cfg.max_waiting,
        }
    }

    /// Typed restore gate: a snapshot taken under one config must not
    /// be rehydrated into an engine running another.  Model mismatches
    /// are called out by registry name — the common operator error is
    /// `--restore` with a different `--model`.
    pub fn check(&self, engine: &ConfigFingerprint) -> Result<(), ConfigMismatch> {
        if self == engine {
            Ok(())
        } else {
            Err(ConfigMismatch { snapshot: *self, engine: *engine })
        }
    }
}

/// Restore refused: the snapshot's [`ConfigFingerprint`] differs from
/// the engine's.  Carries both sides so callers (and the CLI) can say
/// exactly which config the snapshot wants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConfigMismatch {
    pub snapshot: ConfigFingerprint,
    pub engine: ConfigFingerprint,
}

impl std::fmt::Display for ConfigMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.snapshot.model != self.engine.model {
            write!(
                f,
                "config mismatch: snapshot was taken under model `{}` ({:?}) but the engine \
                 is configured for model `{}` ({:?}); rerun with the snapshot's model",
                self.snapshot.model.name,
                self.snapshot.model,
                self.engine.model.name,
                self.engine.model,
            )
        } else {
            write!(
                f,
                "config mismatch: snapshot {:?} vs engine {:?}",
                self.snapshot, self.engine
            )
        }
    }
}

impl std::error::Error for ConfigMismatch {}

/// One sequence plus the sampler RNG stream that continues it.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqSnap {
    pub seq: Sequence,
    pub rng: ([u64; 4], Option<f64>),
}

/// One not-yet-arrived request plus its (still virgin) RNG stream.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingSnap {
    pub req: Request,
    pub rng: ([u64; 4], Option<f64>),
}

/// Scheduler counters + the fault schedule's replayable draw state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SchedSnap {
    pub preemption_count: usize,
    pub prefill_tokens_skipped: usize,
    pub swap_out_count: usize,
    pub swap_out_mid_prefill: usize,
    pub swap_out_mid_decode: usize,
    pub swap_in_count: usize,
    pub swap_restored_tokens: usize,
    pub shed_count: usize,
    pub fault_draws: [u64; N_SEAMS],
    pub fault_fired: [u64; N_SEAMS],
}

/// Everything [`crate::engine::Engine`] needs to resume exactly where a
/// quiescent step boundary left off (see module docs for the record
/// map).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSnapshot {
    pub config: ConfigFingerprint,
    pub clock: f64,
    pub consecutive_step_failures: u32,
    pub fault_stalls: usize,
    /// Every sequence the scheduler has seen (finished ones included —
    /// their ids must stay burned), sorted by id.
    pub sequences: Vec<SeqSnap>,
    /// Requests whose arrival the clock has not reached, sorted by id.
    pub pending: Vec<PendingSnap>,
    pub waiting: Vec<usize>,
    pub running: Vec<usize>,
    pub prefilling: Vec<usize>,
    pub sched: SchedSnap,
    pub blocks: BlockManagerState,
    /// Terminal outcomes, resolution order.
    pub outcomes: Vec<(usize, RequestOutcome)>,
    pub outputs: Vec<RequestOutput>,
    pub metrics: Metrics,
    /// Live (refcount > 0) block ids, ascending — the rows `kv_payload`
    /// covers, in order.
    pub kv_blocks: Vec<BlockId>,
    /// Packed pool payload of `kv_blocks` (None for virtual backends).
    pub kv_payload: Option<KvSpill>,
    /// Swapped-out sequences' host-side spills: (seq id, spilled block
    /// count, payload — None when the backend prices bytes only).
    pub spills: Vec<(usize, usize, Option<KvSpill>)>,
}

// ---------------------------------------------------------------- writer

struct Buf(Vec<u8>);

impl Buf {
    fn new() -> Buf {
        Buf(Vec::new())
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.0.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn us(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn i64(&mut self, v: i64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u32(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }
    fn str(&mut self, s: &str) {
        self.us(s.len());
        self.0.extend_from_slice(s.as_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.us(b.len());
        self.0.extend_from_slice(b);
    }
    fn vec_u32(&mut self, v: &[u32]) {
        self.us(v.len());
        for &x in v {
            self.u32(x);
        }
    }
    fn vec_us(&mut self, v: &[usize]) {
        self.us(v.len());
        for &x in v {
            self.us(x);
        }
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.us(v.len());
        for &x in v {
            self.f64(x);
        }
    }
}

// ---------------------------------------------------------------- reader

struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

type PErr = String;

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, p: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], PErr> {
        if self.p + n > self.b.len() {
            return Err(format!("short read: need {n} bytes at offset {}", self.p));
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }
    fn done(&self) -> bool {
        self.p == self.b.len()
    }
    fn u8(&mut self) -> Result<u8, PErr> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> Result<bool, PErr> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("bad bool byte {v}")),
        }
    }
    fn u32(&mut self) -> Result<u32, PErr> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, PErr> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn us(&mut self) -> Result<usize, PErr> {
        Ok(self.u64()? as usize)
    }
    fn i64(&mut self) -> Result<i64, PErr> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, PErr> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64, PErr> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn opt_f64(&mut self) -> Result<Option<f64>, PErr> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }
    fn opt_u32(&mut self) -> Result<Option<u32>, PErr> {
        Ok(if self.bool()? { Some(self.u32()?) } else { None })
    }
    fn opt_u64(&mut self) -> Result<Option<u64>, PErr> {
        Ok(if self.bool()? { Some(self.u64()?) } else { None })
    }
    /// Bounded length prefix: a corrupt length must fail here, not OOM.
    fn len(&mut self) -> Result<usize, PErr> {
        let n = self.us()?;
        if n > self.b.len() - self.p.min(self.b.len()) {
            return Err(format!("length {n} exceeds remaining payload"));
        }
        Ok(n)
    }
    fn str(&mut self) -> Result<String, PErr> {
        let n = self.len()?;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|e| format!("bad utf8: {e}"))
    }
    fn bytes(&mut self) -> Result<Vec<u8>, PErr> {
        let n = self.len()?;
        Ok(self.take(n)?.to_vec())
    }
    fn vec_u32(&mut self) -> Result<Vec<u32>, PErr> {
        let n = self.len()?;
        (0..n).map(|_| self.u32()).collect()
    }
    fn vec_us(&mut self) -> Result<Vec<usize>, PErr> {
        let n = self.len()?;
        (0..n).map(|_| self.us()).collect()
    }
    fn vec_f64(&mut self) -> Result<Vec<f64>, PErr> {
        let n = self.len()?;
        (0..n).map(|_| self.f64()).collect()
    }
}

// ------------------------------------------------- component encodings

fn put_sampling(b: &mut Buf, s: &SamplingParams) {
    b.f32(s.temperature);
    b.us(s.top_k);
    b.us(s.max_tokens);
    b.opt_u32(s.stop_token);
    b.u64(s.seed);
}

fn get_sampling(c: &mut Cur<'_>) -> Result<SamplingParams, PErr> {
    Ok(SamplingParams {
        temperature: c.f32()?,
        top_k: c.us()?,
        max_tokens: c.us()?,
        stop_token: c.opt_u32()?,
        seed: c.u64()?,
    })
}

fn put_rng(b: &mut Buf, rng: &([u64; 4], Option<f64>)) {
    for &w in &rng.0 {
        b.u64(w);
    }
    b.opt_f64(rng.1);
}

fn get_rng(c: &mut Cur<'_>) -> Result<([u64; 4], Option<f64>), PErr> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = c.u64()?;
    }
    if s.iter().all(|&x| x == 0) {
        return Err("all-zero RNG state".into());
    }
    Ok((s, c.opt_f64()?))
}

fn put_seq(b: &mut Buf, s: &SeqSnap) {
    let q = &s.seq;
    b.us(q.id);
    b.vec_u32(&q.prompt);
    b.vec_u32(&q.generated);
    put_sampling(b, &q.sampling);
    b.u8(q.state.to_tag());
    b.f64(q.arrival);
    b.i64(q.priority as i64);
    b.opt_f64(q.deadline);
    b.opt_f64(q.admitted_time);
    b.opt_f64(q.first_token_time);
    b.opt_f64(q.finish_time);
    b.us(q.preemptions);
    b.us(q.cached_len);
    b.us(q.prefill_pos);
    put_rng(b, &s.rng);
}

fn get_seq(c: &mut Cur<'_>) -> Result<SeqSnap, PErr> {
    let id = c.us()?;
    let prompt = c.vec_u32()?;
    let generated = c.vec_u32()?;
    let sampling = get_sampling(c)?;
    let tag = c.u8()?;
    let state = SeqState::from_tag(tag).ok_or_else(|| format!("bad SeqState tag {tag}"))?;
    Ok(SeqSnap {
        seq: Sequence {
            id,
            prompt,
            generated,
            sampling,
            state,
            arrival: c.f64()?,
            priority: c.i64()? as i32,
            deadline: c.opt_f64()?,
            admitted_time: c.opt_f64()?,
            first_token_time: c.opt_f64()?,
            finish_time: c.opt_f64()?,
            preemptions: c.us()?,
            cached_len: c.us()?,
            prefill_pos: c.us()?,
        },
        rng: get_rng(c)?,
    })
}

fn put_outcome(b: &mut Buf, o: &RequestOutcome) {
    match o {
        RequestOutcome::Completed => b.u8(0),
        RequestOutcome::Rejected { reason } => {
            b.u8(1);
            b.str(reason);
        }
        RequestOutcome::TimedOut => b.u8(2),
        RequestOutcome::Cancelled => b.u8(3),
        RequestOutcome::Failed { reason } => {
            b.u8(4);
            b.str(reason);
        }
    }
}

fn get_outcome(c: &mut Cur<'_>) -> Result<RequestOutcome, PErr> {
    Ok(match c.u8()? {
        0 => RequestOutcome::Completed,
        1 => RequestOutcome::Rejected { reason: c.str()? },
        2 => RequestOutcome::TimedOut,
        3 => RequestOutcome::Cancelled,
        4 => RequestOutcome::Failed { reason: c.str()? },
        t => return Err(format!("bad RequestOutcome tag {t}")),
    })
}

fn put_spill_side(b: &mut Buf, s: &SpillSide) {
    match s {
        SpillSide::F32(v) => {
            b.u8(0);
            b.us(v.len());
            for &x in v {
                b.f32(x);
            }
        }
        SpillSide::F16(v) => {
            b.u8(1);
            b.us(v.len());
            for &x in v {
                b.0.extend_from_slice(&x.to_le_bytes());
            }
        }
        SpillSide::Kv4 { packed, scale, zero } => {
            b.u8(2);
            b.bytes(packed);
            b.us(scale.len());
            for &x in scale {
                b.f32(x);
            }
            b.us(zero.len());
            for &x in zero {
                b.f32(x);
            }
        }
    }
}

fn get_spill_side(c: &mut Cur<'_>) -> Result<SpillSide, PErr> {
    Ok(match c.u8()? {
        0 => {
            let n = c.len()?;
            SpillSide::F32((0..n).map(|_| c.f32()).collect::<Result<_, _>>()?)
        }
        1 => {
            let n = c.len()?;
            SpillSide::F16(
                (0..n)
                    .map(|_| c.take(2).map(|s| u16::from_le_bytes(s.try_into().unwrap())))
                    .collect::<Result<_, _>>()?,
            )
        }
        2 => {
            let packed = c.bytes()?;
            let ns = c.len()?;
            let scale = (0..ns).map(|_| c.f32()).collect::<Result<_, _>>()?;
            let nz = c.len()?;
            let zero = (0..nz).map(|_| c.f32()).collect::<Result<_, _>>()?;
            SpillSide::Kv4 { packed, scale, zero }
        }
        t => return Err(format!("bad SpillSide tag {t}")),
    })
}

fn put_kv_spill(b: &mut Buf, s: &KvSpill) {
    b.str(s.dtype().name());
    b.us(s.n_blocks());
    put_spill_side(b, s.k());
    put_spill_side(b, s.v());
}

fn get_kv_spill(c: &mut Cur<'_>) -> Result<KvSpill, PErr> {
    let name = c.str()?;
    let dtype = KvDtype::parse(&name).ok_or_else(|| format!("bad KV dtype {name:?}"))?;
    let n_blocks = c.us()?;
    let k = get_spill_side(c)?;
    let v = get_spill_side(c)?;
    Ok(KvSpill::from_parts(dtype, n_blocks, k, v))
}

fn put_opt_kv_spill(b: &mut Buf, s: &Option<KvSpill>) {
    match s {
        Some(x) => {
            b.u8(1);
            put_kv_spill(b, x);
        }
        None => b.u8(0),
    }
}

fn get_opt_kv_spill(c: &mut Cur<'_>) -> Result<Option<KvSpill>, PErr> {
    Ok(if c.bool()? { Some(get_kv_spill(c)?) } else { None })
}

// ------------------------------------------------------ (de)serialization

fn record(out: &mut Vec<u8>, tag: u8, body: impl FnOnce(&mut Buf)) {
    let mut b = Buf::new();
    b.u8(tag);
    body(&mut b);
    out.extend_from_slice(&(b.0.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&b.0).to_le_bytes());
    out.extend_from_slice(&b.0);
}

impl EngineSnapshot {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let fp = &self.config;
        record(&mut out, TAG_CONFIG, |b| {
            b.us(fp.max_batch);
            b.us(fp.block_size);
            b.us(fp.total_blocks);
            b.us(fp.max_seq_len);
            b.us(fp.prefill_budget);
            b.bool(fp.prefix_skip);
            b.bool(fp.swap_preempt);
            b.str(fp.kv_dtype.name());
            b.us(fp.max_waiting);
            let m = &fp.model;
            b.str(m.name);
            b.us(m.n_layers);
            b.us(m.d_model);
            b.us(m.n_heads);
            b.us(m.n_kv_heads);
            b.us(m.d_ff);
            b.us(m.vocab);
            b.us(m.group_size);
            b.bool(m.rope);
            b.us(m.max_seq);
            b.us(m.max_batch);
            b.u64(m.seed);
        });
        record(&mut out, TAG_META, |b| {
            b.f64(self.clock);
            b.u32(self.consecutive_step_failures);
            b.us(self.fault_stalls);
        });
        for s in &self.sequences {
            record(&mut out, TAG_SEQ, |b| put_seq(b, s));
        }
        for p in &self.pending {
            record(&mut out, TAG_PENDING, |b| {
                b.us(p.req.id);
                b.vec_u32(&p.req.prompt);
                put_sampling(b, &p.req.sampling);
                b.f64(p.req.arrival);
                b.i64(p.req.priority as i64);
                b.opt_f64(p.req.deadline);
                put_rng(b, &p.rng);
            });
        }
        record(&mut out, TAG_QUEUES, |b| {
            b.vec_us(&self.waiting);
            b.vec_us(&self.running);
            b.vec_us(&self.prefilling);
        });
        record(&mut out, TAG_SCHED, |b| {
            let s = &self.sched;
            b.us(s.preemption_count);
            b.us(s.prefill_tokens_skipped);
            b.us(s.swap_out_count);
            b.us(s.swap_out_mid_prefill);
            b.us(s.swap_out_mid_decode);
            b.us(s.swap_in_count);
            b.us(s.swap_restored_tokens);
            b.us(s.shed_count);
            for &d in &s.fault_draws {
                b.u64(d);
            }
            for &f in &s.fault_fired {
                b.u64(f);
            }
        });
        record(&mut out, TAG_BLOCKS, |b| {
            let st = &self.blocks;
            b.us(st.block_size);
            b.us(st.blocks.len());
            for &(rc, hash, computed) in &st.blocks {
                b.us(rc);
                b.opt_u64(hash);
                b.bool(computed);
            }
            b.vec_us(&st.free);
            b.us(st.prefix_index.len());
            for &(h, blk) in &st.prefix_index {
                b.u64(h);
                b.us(blk);
            }
            b.us(st.tables.len());
            for (id, table) in &st.tables {
                b.us(*id);
                b.vec_us(table);
            }
            b.us(st.swapped.len());
            for &(id, n) in &st.swapped {
                b.us(id);
                b.us(n);
            }
            b.us(st.prefix_hits);
        });
        record(&mut out, TAG_OUTCOMES, |b| {
            b.us(self.outcomes.len());
            for (id, o) in &self.outcomes {
                b.us(*id);
                put_outcome(b, o);
            }
        });
        record(&mut out, TAG_OUTPUTS, |b| {
            b.us(self.outputs.len());
            for o in &self.outputs {
                b.us(o.id);
                b.us(o.prompt_len);
                b.vec_u32(&o.tokens);
                b.u8(match o.finish {
                    FinishReason::MaxTokens => 0,
                    FinishReason::StopToken => 1,
                    FinishReason::LengthCap => 2,
                });
                b.f64(o.ttft);
                b.f64(o.latency);
                b.us(o.preemptions);
            }
        });
        record(&mut out, TAG_METRICS, |b| {
            let m = &self.metrics;
            b.f64(m.elapsed);
            b.us(m.prompt_tokens);
            b.us(m.output_tokens);
            b.us(m.engine_steps);
            b.us(m.prefill_steps);
            b.us(m.decode_steps);
            b.us(m.preemptions);
            b.us(m.prefill_chunks);
            b.us(m.prefill_tokens_skipped);
            b.us(m.decode_batch_sum);
            b.vec_f64(&m.latencies);
            b.vec_f64(&m.ttfts);
            b.vec_f64(&m.queue_times);
            b.vec_f64(&m.tpots);
            b.us(m.swap_outs);
            b.us(m.swap_ins);
            b.us(m.swap_restored_tokens);
            b.us(m.swap_spilled_bytes);
            b.us(m.kv_pool_bytes);
            b.us(m.kv_bytes_per_token);
            b.us(m.kv_spill_peak_bytes);
            b.us(m.shed_requests);
            b.us(m.rejected_requests);
            b.us(m.timed_out_requests);
            b.us(m.cancelled_requests);
            b.us(m.failed_requests);
            b.us(m.step_retries);
            b.us(m.spill_faults);
            b.us(m.checkpoints_written);
            b.us(m.restored_requests);
            b.us(m.goodput_tokens);
        });
        record(&mut out, TAG_KV, |b| {
            b.vec_us(&self.kv_blocks);
            put_opt_kv_spill(b, &self.kv_payload);
        });
        for (id, n, payload) in &self.spills {
            record(&mut out, TAG_SPILL, |b| {
                b.us(*id);
                b.us(*n);
                put_opt_kv_spill(b, payload);
            });
        }
        record(&mut out, TAG_END, |_| {});
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<EngineSnapshot, PErr> {
        if data.len() < MAGIC.len() + 4 {
            return Err("file shorter than the header".into());
        }
        if &data[..8] != MAGIC {
            return Err("bad magic (not a snapshot file)".into());
        }
        let version = u32::from_le_bytes(data[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(format!("unsupported snapshot version {version}"));
        }

        let mut config = None;
        let mut meta = None;
        let mut sequences = Vec::new();
        let mut pending = Vec::new();
        let mut queues = None;
        let mut sched = None;
        let mut blocks = None;
        let mut outcomes = None;
        let mut outputs = None;
        let mut metrics = None;
        let mut kv = None;
        let mut spills = Vec::new();
        let mut ended = false;

        let mut rest = &data[12..];
        while !rest.is_empty() {
            if ended {
                return Err("trailing bytes after END record".into());
            }
            if rest.len() < 8 {
                return Err("torn record header".into());
            }
            let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(rest[4..8].try_into().unwrap());
            if rest.len() < 8 + len {
                return Err(format!("torn record: {len} payload bytes, {} present", rest.len() - 8));
            }
            let payload = &rest[8..8 + len];
            if crc32(payload) != crc {
                return Err("record CRC mismatch (corrupt write)".into());
            }
            rest = &rest[8 + len..];

            let mut c = Cur::new(payload);
            let tag = c.u8()?;
            match tag {
                TAG_CONFIG => {
                    let max_batch = c.us()?;
                    let block_size = c.us()?;
                    let total_blocks = c.us()?;
                    let max_seq_len = c.us()?;
                    let prefill_budget = c.us()?;
                    let prefix_skip = c.bool()?;
                    let swap_preempt = c.bool()?;
                    let kv_dtype = {
                        let name = c.str()?;
                        KvDtype::parse(&name).ok_or_else(|| format!("bad KV dtype {name:?}"))?
                    };
                    let max_waiting = c.us()?;
                    // Model shape: the registry name pins the &'static
                    // label; the dims travel alongside so a snapshot
                    // under a seed-overridden config round-trips exactly.
                    let model = {
                        let name = c.str()?;
                        let base = crate::models::static_by_name(&name)
                            .ok_or_else(|| format!("unknown model config {name:?} in snapshot"))?;
                        crate::models::ModelConfig {
                            name: base.name,
                            n_layers: c.us()?,
                            d_model: c.us()?,
                            n_heads: c.us()?,
                            n_kv_heads: c.us()?,
                            d_ff: c.us()?,
                            vocab: c.us()?,
                            group_size: c.us()?,
                            rope: c.bool()?,
                            max_seq: c.us()?,
                            max_batch: c.us()?,
                            seed: c.u64()?,
                        }
                    };
                    config = Some(ConfigFingerprint {
                        model,
                        max_batch,
                        block_size,
                        total_blocks,
                        max_seq_len,
                        prefill_budget,
                        prefix_skip,
                        swap_preempt,
                        kv_dtype,
                        max_waiting,
                    });
                }
                TAG_META => meta = Some((c.f64()?, c.u32()?, c.us()?)),
                TAG_SEQ => sequences.push(get_seq(&mut c)?),
                TAG_PENDING => {
                    let id = c.us()?;
                    let prompt = c.vec_u32()?;
                    let sampling = get_sampling(&mut c)?;
                    pending.push(PendingSnap {
                        req: Request {
                            id,
                            prompt,
                            sampling,
                            arrival: c.f64()?,
                            priority: c.i64()? as i32,
                            deadline: c.opt_f64()?,
                        },
                        rng: get_rng(&mut c)?,
                    });
                }
                TAG_QUEUES => queues = Some((c.vec_us()?, c.vec_us()?, c.vec_us()?)),
                TAG_SCHED => {
                    let mut s = SchedSnap {
                        preemption_count: c.us()?,
                        prefill_tokens_skipped: c.us()?,
                        swap_out_count: c.us()?,
                        swap_out_mid_prefill: c.us()?,
                        swap_out_mid_decode: c.us()?,
                        swap_in_count: c.us()?,
                        swap_restored_tokens: c.us()?,
                        shed_count: c.us()?,
                        fault_draws: [0; N_SEAMS],
                        fault_fired: [0; N_SEAMS],
                    };
                    for d in &mut s.fault_draws {
                        *d = c.u64()?;
                    }
                    for f in &mut s.fault_fired {
                        *f = c.u64()?;
                    }
                    sched = Some(s);
                }
                TAG_BLOCKS => {
                    let block_size = c.us()?;
                    let nb = c.len()?;
                    let mut bl = Vec::with_capacity(nb);
                    for _ in 0..nb {
                        bl.push((c.us()?, c.opt_u64()?, c.bool()?));
                    }
                    let free = c.vec_us()?;
                    let npi = c.len()?;
                    let mut prefix_index = Vec::with_capacity(npi);
                    for _ in 0..npi {
                        prefix_index.push((c.u64()?, c.us()?));
                    }
                    let nt = c.len()?;
                    let mut tables = Vec::with_capacity(nt);
                    for _ in 0..nt {
                        tables.push((c.us()?, c.vec_us()?));
                    }
                    let nsw = c.len()?;
                    let mut swapped = Vec::with_capacity(nsw);
                    for _ in 0..nsw {
                        swapped.push((c.us()?, c.us()?));
                    }
                    blocks = Some(BlockManagerState {
                        block_size,
                        blocks: bl,
                        free,
                        prefix_index,
                        tables,
                        swapped,
                        prefix_hits: c.us()?,
                    });
                }
                TAG_OUTCOMES => {
                    let n = c.len()?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        let id = c.us()?;
                        v.push((id, get_outcome(&mut c)?));
                    }
                    outcomes = Some(v);
                }
                TAG_OUTPUTS => {
                    let n = c.len()?;
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(RequestOutput {
                            id: c.us()?,
                            prompt_len: c.us()?,
                            tokens: c.vec_u32()?,
                            finish: match c.u8()? {
                                0 => FinishReason::MaxTokens,
                                1 => FinishReason::StopToken,
                                2 => FinishReason::LengthCap,
                                t => return Err(format!("bad FinishReason tag {t}")),
                            },
                            ttft: c.f64()?,
                            latency: c.f64()?,
                            preemptions: c.us()?,
                        });
                    }
                    outputs = Some(v);
                }
                TAG_METRICS => {
                    metrics = Some(Metrics {
                        elapsed: c.f64()?,
                        prompt_tokens: c.us()?,
                        output_tokens: c.us()?,
                        engine_steps: c.us()?,
                        prefill_steps: c.us()?,
                        decode_steps: c.us()?,
                        preemptions: c.us()?,
                        prefill_chunks: c.us()?,
                        prefill_tokens_skipped: c.us()?,
                        decode_batch_sum: c.us()?,
                        latencies: c.vec_f64()?,
                        ttfts: c.vec_f64()?,
                        queue_times: c.vec_f64()?,
                        tpots: c.vec_f64()?,
                        swap_outs: c.us()?,
                        swap_ins: c.us()?,
                        swap_restored_tokens: c.us()?,
                        swap_spilled_bytes: c.us()?,
                        kv_pool_bytes: c.us()?,
                        kv_bytes_per_token: c.us()?,
                        kv_spill_peak_bytes: c.us()?,
                        shed_requests: c.us()?,
                        rejected_requests: c.us()?,
                        timed_out_requests: c.us()?,
                        cancelled_requests: c.us()?,
                        failed_requests: c.us()?,
                        step_retries: c.us()?,
                        spill_faults: c.us()?,
                        checkpoints_written: c.us()?,
                        restored_requests: c.us()?,
                        goodput_tokens: c.us()?,
                    });
                }
                TAG_KV => kv = Some((c.vec_us()?, get_opt_kv_spill(&mut c)?)),
                TAG_SPILL => {
                    let id = c.us()?;
                    let n = c.us()?;
                    spills.push((id, n, get_opt_kv_spill(&mut c)?));
                }
                TAG_END => ended = true,
                t => return Err(format!("unknown record tag {t}")),
            }
            if tag != TAG_END && !c.done() {
                return Err(format!("record tag {tag} has {} trailing bytes", payload.len() - c.p));
            }
        }
        if !ended {
            return Err("missing END record (torn snapshot)".into());
        }

        let (clock, consecutive_step_failures, fault_stalls) =
            meta.ok_or("missing META record")?;
        let (waiting, running, prefilling) = queues.ok_or("missing QUEUES record")?;
        let (kv_blocks, kv_payload) = kv.ok_or("missing KV record")?;
        Ok(EngineSnapshot {
            config: config.ok_or("missing CONFIG record")?,
            clock,
            consecutive_step_failures,
            fault_stalls,
            sequences,
            pending,
            waiting,
            running,
            prefilling,
            sched: sched.ok_or("missing SCHED record")?,
            blocks: blocks.ok_or("missing BLOCKS record")?,
            outcomes: outcomes.ok_or("missing OUTCOMES record")?,
            outputs: outputs.ok_or("missing OUTPUTS record")?,
            metrics: metrics.ok_or("missing METRICS record")?,
            kv_blocks,
            kv_payload,
            spills,
        })
    }
}

// ------------------------------------------------------ file management

fn snapshot_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snap-{seq:06}.bin"))
}

/// (seq, path) of every `snap-NNNNNN.bin` in `dir`, ascending by seq.
fn list_snapshots(dir: &Path) -> Vec<(u64, PathBuf)> {
    let Ok(rd) = fs::read_dir(dir) else { return Vec::new() };
    let mut out: Vec<(u64, PathBuf)> = rd
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let seq = name.strip_prefix("snap-")?.strip_suffix(".bin")?.parse().ok()?;
            Some((seq, e.path()))
        })
        .collect();
    out.sort_unstable_by_key(|&(seq, _)| seq);
    out
}

/// The sequence number the next snapshot in `dir` should use.
pub fn next_seq(dir: &Path) -> u64 {
    list_snapshots(dir).last().map_or(0, |&(seq, _)| seq + 1)
}

/// Commit one snapshot: serialize, write `snap-NNNNNN.tmp`, fsync, and
/// atomically rename to `.bin` — a crash at any point leaves either the
/// previous snapshots untouched or a stray `.tmp` that the reader never
/// looks at.  Older snapshots beyond [`KEEP_SNAPSHOTS`] are pruned
/// after the rename.
pub fn write_snapshot(dir: &Path, seq: u64, snap: &EngineSnapshot) -> std::io::Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let bytes = snap.to_bytes();
    let tmp = dir.join(format!("snap-{seq:06}.tmp"));
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    let path = snapshot_path(dir, seq);
    fs::rename(&tmp, &path)?;
    let existing = list_snapshots(dir);
    if existing.len() > KEEP_SNAPSHOTS {
        for (_, old) in &existing[..existing.len() - KEEP_SNAPSHOTS] {
            let _ = fs::remove_file(old);
        }
    }
    Ok(path)
}

/// Load the newest snapshot in `dir` that parses clean, skipping (and
/// reporting on total failure) torn or corrupt trailing files —
/// crash-during-commit recovery falls back to the previous commit.
/// `Ok(None)` when the directory holds no snapshot files at all.
pub fn load_latest(dir: &Path) -> Result<Option<(u64, EngineSnapshot)>, PErr> {
    let mut files = list_snapshots(dir);
    files.reverse();
    if files.is_empty() {
        return Ok(None);
    }
    let mut errors = Vec::new();
    for (seq, path) in files {
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) => {
                errors.push(format!("{}: {e}", path.display()));
                continue;
            }
        };
        match EngineSnapshot::from_bytes(&bytes) {
            Ok(snap) => {
                if !errors.is_empty() {
                    eprintln!(
                        "opt4gptq: falling back to snapshot {seq}: {}",
                        errors.join("; ")
                    );
                }
                return Ok(Some((seq, snap)));
            }
            Err(e) => errors.push(format!("{}: {e}", path.display())),
        }
    }
    Err(format!("no valid snapshot in {}: {}", dir.display(), errors.join("; ")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampling() -> SamplingParams {
        SamplingParams { temperature: 0.9, top_k: 24, max_tokens: 32, stop_token: Some(7), seed: 3 }
    }

    fn snap() -> EngineSnapshot {
        let seq = Sequence {
            id: 4,
            prompt: vec![1, 2, 3, 4, 5],
            generated: vec![9, 8],
            sampling: sampling(),
            state: SeqState::Running,
            arrival: 0.25,
            priority: -2,
            deadline: Some(9.5),
            admitted_time: Some(0.5),
            first_token_time: Some(1.0),
            finish_time: None,
            preemptions: 1,
            cached_len: 2,
            prefill_pos: 6,
        };
        let mut swapped_seq = seq.clone();
        swapped_seq.id = 5;
        swapped_seq.state = SeqState::Swapped;
        EngineSnapshot {
            config: ConfigFingerprint {
                model: crate::models::ModelConfig {
                    seed: 0x5eed,
                    ..crate::models::TINY_GQA
                },
                max_batch: 4,
                block_size: 4,
                total_blocks: 24,
                max_seq_len: 128,
                prefill_budget: 8,
                prefix_skip: true,
                swap_preempt: true,
                kv_dtype: KvDtype::Kv4,
                max_waiting: usize::MAX,
            },
            clock: 12.75,
            consecutive_step_failures: 2,
            fault_stalls: 1,
            sequences: vec![
                SeqSnap { seq, rng: ([1, 2, 3, 4], Some(0.5)) },
                SeqSnap { seq: swapped_seq, rng: ([5, 6, 7, 8], None) },
            ],
            pending: vec![PendingSnap {
                req: Request {
                    id: 9,
                    prompt: vec![4, 4, 4],
                    sampling: sampling(),
                    arrival: 40.0,
                    priority: 3,
                    deadline: None,
                },
                rng: ([9, 0, 0, 1], None),
            }],
            waiting: vec![5],
            running: vec![4],
            prefilling: vec![],
            sched: SchedSnap {
                preemption_count: 3,
                prefill_tokens_skipped: 2,
                swap_out_count: 1,
                swap_out_mid_prefill: 0,
                swap_out_mid_decode: 1,
                swap_in_count: 0,
                swap_restored_tokens: 0,
                shed_count: 0,
                fault_draws: [1, 2, 3, 4, 5, 6, 7, 8],
                fault_fired: [0, 1, 0, 1, 0, 1, 0, 1],
            },
            blocks: BlockManagerState {
                block_size: 4,
                blocks: vec![(1, Some(0xfeed), true), (0, None, false), (2, None, true)],
                free: vec![1],
                prefix_index: vec![(0xfeed, 0)],
                tables: vec![(4, vec![0, 2, 2])],
                swapped: vec![(5, 2)],
                prefix_hits: 6,
            },
            outcomes: vec![
                (2, RequestOutcome::Completed),
                (1, RequestOutcome::Rejected { reason: "shed".into() }),
                (3, RequestOutcome::Cancelled),
                (6, RequestOutcome::Failed { reason: "ecc".into() }),
                (7, RequestOutcome::TimedOut),
            ],
            outputs: vec![RequestOutput {
                id: 2,
                prompt_len: 5,
                tokens: vec![11, 12, 13],
                finish: FinishReason::StopToken,
                ttft: 0.5,
                latency: 2.0,
                preemptions: 0,
            }],
            metrics: Metrics {
                elapsed: 12.75,
                prompt_tokens: 40,
                output_tokens: 17,
                latencies: vec![2.0],
                ttfts: vec![0.5],
                checkpoints_written: 2,
                cancelled_requests: 1,
                ..Default::default()
            },
            kv_blocks: vec![0, 2],
            kv_payload: Some(KvSpill::from_parts(
                KvDtype::Kv4,
                2,
                SpillSide::Kv4 { packed: vec![0xAB; 16], scale: vec![0.5; 4], zero: vec![0.0; 4] },
                SpillSide::Kv4 { packed: vec![0xCD; 16], scale: vec![1.5; 4], zero: vec![2.0; 4] },
            )),
            spills: vec![(
                5,
                2,
                Some(KvSpill::from_parts(
                    KvDtype::Kv4,
                    2,
                    SpillSide::Kv4 { packed: vec![1; 8], scale: vec![0.25; 2], zero: vec![0.1; 2] },
                    SpillSide::Kv4 { packed: vec![2; 8], scale: vec![0.75; 2], zero: vec![0.2; 2] },
                )),
            )],
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn snapshot_roundtrips_bit_exactly() {
        let s = snap();
        let bytes = s.to_bytes();
        let back = EngineSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(s, back);
        // All three SpillSide encodings roundtrip too.
        for side in [
            SpillSide::F32(vec![1.0, -2.5, f32::MIN_POSITIVE]),
            SpillSide::F16(vec![0x3C00, 0x8000, 0x7BFF]),
            SpillSide::Kv4 { packed: vec![9, 9], scale: vec![0.5], zero: vec![-1.0] },
        ] {
            let mut b = Buf::new();
            put_spill_side(&mut b, &side);
            let mut c = Cur::new(&b.0);
            assert_eq!(get_spill_side(&mut c).unwrap(), side);
            assert!(c.done());
        }
    }

    #[test]
    fn truncated_tail_is_rejected() {
        let bytes = snap().to_bytes();
        // Any truncation (even at a record boundary: END goes missing)
        // must fail to parse.
        for cut in [bytes.len() - 1, bytes.len() - 13, bytes.len() / 2, 13] {
            assert!(
                EngineSnapshot::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be torn",
                bytes.len()
            );
        }
    }

    #[test]
    fn flipped_byte_is_rejected() {
        let good = snap().to_bytes();
        // Flip one byte in the last quarter (tail records) and in the
        // middle; CRC or structure must catch every single-byte flip.
        for pos in [good.len() - 2, good.len() - 20, good.len() / 2, 20] {
            let mut bad = good.clone();
            bad[pos] ^= 0x41;
            assert!(
                EngineSnapshot::from_bytes(&bad).is_err(),
                "flip at {pos}/{} must be detected",
                good.len()
            );
        }
    }

    #[test]
    fn commit_fallback_skips_torn_tail_snapshot() {
        let dir = std::env::temp_dir().join(format!("o4g-persist-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert!(load_latest(&dir).unwrap().is_none(), "empty dir has no snapshot");

        let s = snap();
        write_snapshot(&dir, 0, &s).unwrap();
        assert_eq!(next_seq(&dir), 1);
        let (seq, back) = load_latest(&dir).unwrap().unwrap();
        assert_eq!((seq, &back), (0, &s));

        // A newer snapshot normally wins...
        let mut s1 = s.clone();
        s1.clock = 99.0;
        write_snapshot(&dir, 1, &s1).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().0, 1);

        // ...but a torn newer commit falls back to the previous one.
        let p1 = snapshot_path(&dir, 1);
        let bytes = fs::read(&p1).unwrap();
        fs::write(&p1, &bytes[..bytes.len() - 7]).unwrap();
        let (seq, back) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(seq, 0);
        assert_eq!(back.clock, s.clock);

        // A corrupt (bit-flipped) newer commit falls back the same way.
        let mut flipped = bytes.clone();
        let n = flipped.len();
        flipped[n - 9] ^= 0xFF;
        fs::write(&p1, &flipped).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().0, 0);

        // All snapshots corrupt -> hard error, not silent empty state.
        let p0 = snapshot_path(&dir, 0);
        let b0 = fs::read(&p0).unwrap();
        fs::write(&p0, &b0[..10]).unwrap();
        assert!(load_latest(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn old_snapshots_are_pruned() {
        let dir = std::env::temp_dir().join(format!("o4g-prune-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let s = snap();
        for seq in 0..(KEEP_SNAPSHOTS as u64 + 3) {
            write_snapshot(&dir, seq, &s).unwrap();
        }
        let left = list_snapshots(&dir);
        assert_eq!(left.len(), KEEP_SNAPSHOTS);
        assert_eq!(left.last().unwrap().0, KEEP_SNAPSHOTS as u64 + 2);
        let _ = fs::remove_dir_all(&dir);
    }
}

//! vLLM-style serving engine (the Layer-3 coordinator).
//!
//! Reproduces the serving stack the paper measures *through*: paged
//! KV-cache accounting ([`block_manager`]) over physically-paged K/V
//! storage ([`kv`]), continuous batching with a prefill/decode scheduler
//! ([`scheduler`]), sampling ([`sampler`]), and an engine step loop
//! ([`engine`]) driving a pluggable [`backend`].  Block tables flow
//! end-to-end: the scheduler allocates them, the engine threads them
//! through [`backend::PrefillDesc`]/[`backend::DecodeDesc`], and paged
//! backends execute attention through them — a prefix-cache hit in the
//! manager is an aliased read of real memory in the backend:
//!
//! * [`backend::SimBackend`] — advances a *virtual clock* using the
//!   [`crate::perfmodel`] step times of a paper model under a chosen
//!   [`crate::OptConfig`]; used to regenerate Figures 2–3;
//! * [`cpu_backend::CpuBackend`] — real token generation through a tiny
//!   quantized transformer executed in-crate by the fused dequant-GEMM
//!   kernels ([`crate::gptq::fused`]) over a [`kv::PagedKvCache`], wall
//!   clock;
//! * `PjrtBackend` (feature `pjrt`) — real token generation through the
//!   AOT-compiled tiny model on the PJRT CPU client (wall clock).
//!
//! The engine is deliberately single-threaded and deterministic: given a
//! trace and a seed, every scheduling decision replays exactly.

pub mod backend;
pub mod block_manager;
pub mod cpu_backend;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod sequence;
pub mod tokenizer;

pub use backend::{Backend, DecodeDesc, PrefillDesc, SimBackend};
pub use block_manager::{BlockId, BlockManager};
pub use cpu_backend::{CpuBackend, CpuModelConfig};
pub use kv::PagedKvCache;
pub use engine::{Engine, EngineReport};
pub use metrics::Metrics;
pub use request::{FinishReason, Request, RequestOutput, SamplingParams};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use sequence::{SeqState, Sequence};

/// Engine-level configuration (vLLM flag analogues).
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Maximum sequences decoded together (the paper uses batch 32).
    pub max_batch: usize,
    /// KV block size in tokens (vLLM default 16).
    pub block_size: usize,
    /// Total KV blocks available (device memory analogue).
    pub total_blocks: usize,
    /// Max model context (prompt + generation).
    pub max_seq_len: usize,
    /// Max prefills admitted per engine step.
    pub max_prefills_per_step: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 32,
            block_size: 16,
            total_blocks: 4096,
            max_seq_len: 2048,
            max_prefills_per_step: 4,
        }
    }
}

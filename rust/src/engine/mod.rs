//! vLLM-style serving engine (the Layer-3 coordinator).
//!
//! Reproduces the serving stack the paper measures *through*: paged
//! KV-cache accounting ([`block_manager`]) over physically-paged K/V
//! storage ([`kv`]), continuous batching with a chunked-prefill
//! scheduler ([`scheduler`]), sampling ([`sampler`]), and an engine
//! step loop ([`engine`]) driving a pluggable [`backend`].  Block
//! tables flow end-to-end: the scheduler allocates them, the engine
//! threads them through [`backend::PrefillDesc`]/[`backend::DecodeDesc`],
//! and paged backends execute attention through them — a prefix-cache
//! hit in the manager is an aliased read of real memory in the backend.
//!
//! **Chunked mixed-batch steps.** Every engine step is one
//! [`backend::Backend::step`] call: the whole decode batch plus as many
//! prefill chunk tokens as [`EngineConfig::prefill_budget`] allows,
//! folded into a single forward pass.  Long prompts stream in
//! block-aligned chunks across steps (decode latency stays bounded;
//! the fused GEMM runs at M ≫ 1 during prefill), with per-sequence
//! progress tracked in [`sequence::Sequence::prefill_pos`].
//!
//! **The `cached_len` contract.** [`block_manager::BlockManager::allocate`]
//! returns the number of leading prompt tokens whose K/V already live
//! in fully-shared *and fully-computed* prefix blocks.  With
//! [`EngineConfig::prefix_skip`] on (the default;
//! `OPT4GPTQ_PREFIX_SKIP=0` flips it), those tokens never reach the
//! backend: the first chunk starts at `cached_len` — a prefix-cache hit
//! is shared *compute*, not just shared memory.  Blocks become
//! "computed" only when the owning sequence's prefill passes them
//! ([`block_manager::BlockManager::mark_computed`]), so a prompt
//! sharing blocks with a still-prefilling peer shares memory but
//! recomputes — never reads K/V that does not exist yet.  The skip and
//! recompute paths are bit-identical (pinned by
//! `rust/tests/backend_integration.rs` and `benches/prefix_prefill.rs`).
//!
//! **Arrival clock and admission.** Requests carry a virtual arrival
//! time ([`request::Request::arrival`]): the engine holds each one in a
//! pending set, invisible to the scheduler, until the engine clock
//! reaches its arrival — when every admitted sequence has drained and
//! arrivals remain, the clock jumps forward to the next one.  Admission
//! order is priority-then-FCFS (higher [`request::Request::priority`]
//! first; ties by arrival, then id), with resumed victims ahead of
//! fresh peers of equal priority, and a fairness guard that defers
//! fresh admissions which would leave the decode batch without append
//! headroom (so a prefill wave cannot starve running decodes into a
//! preemption storm).
//!
//! **Swap lifecycle.** A sequence moves `Waiting → Prefilling → Running
//! → Finished`; under memory pressure a `Prefilling`/`Running` victim
//! either re-enters `Waiting`-like recompute (`Preempted`, the
//! [`EngineConfig::swap_preempt`]` = false` path: blocks freed, prefill
//! restarts from scratch) or becomes `Swapped`: the block manager
//! releases its physical blocks but logs the table, the engine copies
//! the K/V out to the backend's host-side spill pool *before* the
//! blocks can be poisoned or rewritten, and the sequence keeps its
//! exact `prefill_pos`/`cached_len`.  On resume the scheduler allocates
//! fresh blocks (growing the table if a failed self-append left it one
//! block short), the engine restores the spill *before* the next
//! [`backend::Backend::step`], and prefill continues from the cursor —
//! the swapped span is never recomputed, and replay stays bit-identical
//! to an unpreempted run (pinned by `rust/tests/serve_chaos.rs`).
//!
//! **Request lifecycle and outcomes.** Every request resolves to
//! exactly one [`request::RequestOutcome`]:
//!
//! ```text
//!            (arrival clock)        schedule            step/sample
//!  pending ───────────────▶ waiting ─────▶ prefilling ─────▶ running ──▶ completed
//!     │                      │  ▲            │    ▲            │
//!     │ deadline             │  └─ preempt ──┴────┴─ swap ⇄ ───┘
//!     │                      │       (recompute or spill/restore)
//!     ▼                      ▼
//!  timed-out          rejected (shed / never fits)      failed (permanent
//!  (any live state; full     │                           step error or
//!   block+spill reclamation) ▼                           retry exhaustion)
//!                        timed-out
//! ```
//!
//! * **`Completed`** — finished normally; its tokens are bit-identical
//!   to a fault-free run (retries discard the failed step *before* any
//!   sampler RNG or cursor advances).
//! * **`Rejected { reason }`** — never admitted: oversized for the
//!   pool/context (`scheduler`'s progress guarantee resolves the head
//!   instead of stalling the queue), or shed because the bounded
//!   waiting queue ([`EngineConfig::max_waiting`]) was full — shedding
//!   evicts the lowest-priority, latest-arrival *fresh* request, never
//!   a preempted one holding generation progress.
//! * **`TimedOut`** — [`request::Request::deadline`] passed while
//!   pending, waiting, swapped, or mid-generation; the engine cancels
//!   it wherever it is and reclaims blocks and spill entries in full.
//! * **`Cancelled`** — a front-end abort through
//!   [`engine::Engine::cancel`], drained at the next step boundary;
//!   identical reclamation to the deadline path, but caller-initiated.
//! * **`Failed { reason }`** — a permanent backend error, or transient
//!   retries exhausted.
//!
//! **Fault plane.** [`fault::FaultSchedule`] (config: [`EngineConfig::faults`],
//! env default: `OPT4GPTQ_FAULTS`, resolved through [`crate::envcfg`])
//! injects deterministic, seeded failures at the engine↔backend seams:
//!
//! | seam (`fault::FaultSeam`) | where it fires                        | recovery path                                    |
//! |---------------------------|---------------------------------------|--------------------------------------------------|
//! | `StepTransient`           | before [`backend::Backend::step`]     | bounded-backoff retry: batch preempted through the swap/recompute machinery, step discarded |
//! | `StepPermanent`           | before [`backend::Backend::step`]     | scheduled batch resolves `Failed`, engine keeps serving |
//! | `SpillOut`                | before `Backend::swap_out`            | victim demoted to discard-and-recompute          |
//! | `SpillIn`                 | before `Backend::swap_in`             | spill dropped, blocks freed, recompute from zero |
//! | `Alloc`                   | admission headroom / decode append    | admission deferred (engine backs off) / appender preempted |
//! | `MidLayerPoison`          | *inside* the backend forward pass     | one query tile NaN-poisoned between QKV and attention; the backend's finite-logits check fails the step `Permanent` — caught loudly, never silently sampled |
//! | `CrashBeforeCommit`       | checkpoint due, before the write      | process dies; restart resumes from the *previous* snapshot |
//! | `CrashAfterCommit`        | checkpoint committed (renamed)        | process dies; restart resumes from the snapshot just written |
//!
//! Faults fire *before* the backend call they model (`MidLayerPoison`
//! excepted — its whole point is corrupting state mid-forward and
//! proving the backend's own output check catches it), so no backend
//! state is half-mutated; completed-request tokens stay bit-identical
//! to a fault-free run (pinned by `serve_chaos.rs` fault storms and the
//! `properties.rs` trace-replay property).  After every drain,
//! [`engine::Engine::audit`] proves the invariants: no leaked blocks
//! ([`block_manager::BlockManager`] cross-check), no orphaned spill
//! entries, and every freed pool block poisoned-or-never-written
//! ([`kv::PagedKvCache::audit`]).
//!
//! **Crash-consistent checkpoint/restart.** With checkpointing enabled
//! ([`engine::Engine::enable_checkpoints`]; `serve --checkpoint-dir`),
//! every N-th successful step commits the complete engine state to a
//! snapshot file through [`persist`] — sequences with their exact
//! prefill/decode cursors and sampler RNG streams, queue order, block
//! refcounts + prefix index + free-list order, the **packed** K/V
//! payload of every live block at any [`kv::KvDtype`], host-side spill
//! entries, outcomes/outputs/metrics, and the fault schedule's draw
//! counters:
//!
//! ```text
//!   step ▸ drain ─▶ [crash_before?] ─▶ write snap-NNNNNN.tmp
//!                                         │ fsync + rename (atomic)
//!                       prune old ◀── commit ─▶ [crash_after?]
//!
//!   restart: Engine::restore(dir)
//!     └─ newest snapshot that parses clean (CRC per record + END
//!        marker; torn/corrupt tails fall back to the previous commit)
//!     └─ resumes mid-prompt / mid-decode → tokens bit-identical to an
//!        uninterrupted run (pinned by `serve_chaos.rs` kill matrix)
//! ```
//!
//! The same snapshot doubles as **cross-run prefix persistence**: a
//! fresh `serve --restore` process rehydrates computed shared-prefix
//! blocks (index, computed flags, packed K/V), so new requests over the
//! same system prompt skip their cached span without re-prefilling.
//! `OPT4GPTQ_PERSIST=0` disables checkpointing without a rebuild.
//!
//! Backends:
//!
//! * [`backend::SimBackend`] — advances a *virtual clock* using the
//!   [`crate::perfmodel`] step times of a paper model under a chosen
//!   [`crate::OptConfig`]; used to regenerate Figures 2–3;
//! * [`cpu_backend::CpuBackend`] — real token generation through a tiny
//!   quantized transformer executed in-crate by the fused dequant-GEMM
//!   kernels ([`crate::gptq::fused`]) over a [`kv::PagedKvCache`], wall
//!   clock;
//! * `PjrtBackend` (feature `pjrt`) — real token generation through the
//!   AOT-compiled tiny model on the PJRT CPU client (wall clock).
//!
//! The engine is deliberately single-threaded and deterministic: given a
//! trace and a seed, every scheduling decision replays exactly.

pub mod backend;
pub mod block_manager;
pub mod cpu_backend;
pub mod engine;
pub mod fault;
pub mod kv;
pub mod metrics;
pub mod persist;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod sequence;
pub mod tokenizer;

pub use backend::{Backend, DecodeDesc, KvStats, PrefillDesc, SimBackend, StepError, StepOutput};
pub use block_manager::{BlockId, BlockManager};
pub use cpu_backend::{CpuBackend, CpuModelConfig};
pub use fault::{fault_plan_default, FaultPlan, FaultSchedule, FaultSeam};
pub use kv::{KvDtype, KvSpill, PagedKvCache};
pub use engine::{Engine, EngineReport};
pub use metrics::{Metrics, Quantiles};
pub use persist::{ConfigFingerprint, ConfigMismatch, EngineSnapshot};
pub use request::{FinishReason, Request, RequestOutcome, RequestOutput, SamplingParams};
pub use scheduler::{PrefillChunk, ScheduledWork, Scheduler, SchedulerConfig};
pub use sequence::{SeqState, Sequence};

/// Engine-level configuration (vLLM flag analogues).
///
/// The executable model shape comes from the unified
/// [`crate::models::ModelConfig`] registry ([`EngineConfig::model`],
/// `serve --model`, `OPT4GPTQ_MODEL`).  The two tiny executable entries
/// (bytes/token = `2 · n_layers · row_bytes(kv_dim)`):
///
/// | name       | layers | heads | kv heads | RoPE | kv_dim | bytes/token f32/f16/kv4 |
/// |------------|--------|-------|----------|------|--------|-------------------------|
/// | `tiny-mha` | 2      | 4     | 4        | no   | 64     | 1024 / 512 / 160        |
/// | `tiny-gqa` | 2      | 4     | 1        | yes  | 16     | 256 / 128 / 64          |
///
/// plus six `mini-*` Llama/Qwen-shaped entries (see `models::REGISTRY`).
/// The GQA pool shrink (4× at f32/f16, 2.5× at kv4 — the kv4 row pays a
/// fixed 8-byte scale/zero header) multiplies with the KV-dtype shrink:
/// the co-optimization axis the paper argues for.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// The model configuration the backend executes — carried here so
    /// engine snapshots fingerprint the *model* as well as the pool
    /// geometry (a `--restore` under a different model is rejected with
    /// a typed error naming both configs).  Default:
    /// [`crate::models::default_model`] (`tiny-mha`, or `OPT4GPTQ_MODEL`).
    pub model: crate::models::ModelConfig,
    /// Maximum sequences decoded together (the paper uses batch 32).
    pub max_batch: usize,
    /// KV block size in tokens (vLLM default 16).
    pub block_size: usize,
    /// Total KV blocks available (device memory analogue).
    pub total_blocks: usize,
    /// Max model context (prompt + generation).
    pub max_seq_len: usize,
    /// Per-step token budget for prefill chunk tokens (vLLM's
    /// `max_num_batched_tokens` analogue, prefill side): prompts are
    /// processed in block-aligned chunks under this budget, mixed into
    /// the same backend step as the decode batch, so decode latency
    /// stays bounded while prefill saturates the fused GEMM at M ≫ 1.
    /// Clamped to ≥ 1 (one prefill token per step always progresses).
    pub prefill_budget: usize,
    /// Skip the transformer entirely for a prompt's cached prefix (the
    /// leading tokens whose K/V already live in fully-computed shared
    /// prefix blocks).  `OPT4GPTQ_PREFIX_SKIP=0` in the environment
    /// flips the *default* to forced recompute for differential testing;
    /// explicit field settings always win.
    pub prefix_skip: bool,
    /// Preempt by **swapping K/V out** to the backend's host-side spill
    /// pool instead of discarding and recomputing: the victim's blocks
    /// are copied out before they are recycled, and its resume restores
    /// them onto fresh blocks and continues from its exact prefill
    /// cursor — no recompute of the swapped span.  `OPT4GPTQ_SWAP=0`
    /// flips the *default* back to discard-and-recompute (differential
    /// testing); explicit field settings always win.  Victims with
    /// nothing materialized yet fall back to recompute either way.
    pub swap_preempt: bool,
    /// Storage dtype of the paged KV pool (see [`kv::KvDtype`] and the
    /// `engine::kv` module docs table): `F32` is bit-identical to the
    /// pre-quantization cache; `F16`/`Kv4` shrink residency and spill
    /// volume 2×/6.4× at a pinned logit-drift cost.  `OPT4GPTQ_KV`
    /// overrides the *default* (`f32|f16|kv4|auto`, unknown values warn
    /// once and fall back to `f32`); explicit field settings always win.
    pub kv_dtype: KvDtype,
    /// Bound on the scheduler's waiting queue: admitting a fresh request
    /// past this bound sheds the lowest-priority, latest-arrival fresh
    /// waiter (possibly the newcomer itself) as
    /// [`RequestOutcome::Rejected`].  Preempted sequences re-entering
    /// the queue never count against the bound and are never shed —
    /// their generation progress is not discarded by load shedding.
    /// `usize::MAX` (the default) disables shedding.
    pub max_waiting: usize,
    /// Seeded fault-injection plan for the engine↔backend seams (see
    /// [`fault`]).  `OPT4GPTQ_FAULTS` sets the *default*
    /// (`seed=42,step=0.05,...`, warn-once fallback to fault-free on a
    /// bad spec); explicit field settings always win.  The chaos/CI
    /// suites drive storms through this; production configs leave it at
    /// [`FaultPlan::NONE`].
    pub faults: FaultPlan,
}

static PREFIX_SKIP_ENV: std::sync::OnceLock<crate::envcfg::EnvOverride<bool>> =
    std::sync::OnceLock::new();

/// Default for [`EngineConfig::prefix_skip`]: enabled unless the
/// `OPT4GPTQ_PREFIX_SKIP=0` escape hatch is set (differential testing —
/// the recompute path stays reachable without a rebuild).  Resolved
/// warn-once through [`crate::envcfg`].
pub fn prefix_skip_default() -> bool {
    crate::envcfg::env_override(&PREFIX_SKIP_ENV, "OPT4GPTQ_PREFIX_SKIP", |raw| {
        crate::envcfg::parse_bool(raw)
            .map_err(|e| format!("OPT4GPTQ_PREFIX_SKIP: {e} (prefix skip stays on)"))
    })
    .value()
    .copied()
    .unwrap_or(true)
}

static SWAP_ENV: std::sync::OnceLock<crate::envcfg::EnvOverride<bool>> =
    std::sync::OnceLock::new();

/// Default for [`EngineConfig::swap_preempt`]: enabled unless the
/// `OPT4GPTQ_SWAP=0` escape hatch is set (differential testing — the
/// discard-and-recompute path stays reachable without a rebuild).
/// Resolved warn-once through [`crate::envcfg`].
pub fn swap_preempt_default() -> bool {
    crate::envcfg::env_override(&SWAP_ENV, "OPT4GPTQ_SWAP", |raw| {
        crate::envcfg::parse_bool(raw)
            .map_err(|e| format!("OPT4GPTQ_SWAP: {e} (swap preemption stays on)"))
    })
    .value()
    .copied()
    .unwrap_or(true)
}

static PERSIST_ENV: std::sync::OnceLock<crate::envcfg::EnvOverride<bool>> =
    std::sync::OnceLock::new();

/// Whether checkpoint persistence is enabled: on unless the
/// `OPT4GPTQ_PERSIST=0` escape hatch is set (chaos/CI runs that want
/// the kill matrix without disk writes, or serving boxes with no
/// scratch space).  [`engine::Engine::enable_checkpoints`] becomes a
/// no-op when this is off.  Resolved warn-once through
/// [`crate::envcfg`].
pub fn persist_default() -> bool {
    crate::envcfg::env_override(&PERSIST_ENV, "OPT4GPTQ_PERSIST", |raw| {
        crate::envcfg::parse_bool(raw)
            .map_err(|e| format!("OPT4GPTQ_PERSIST: {e} (checkpoint persistence stays on)"))
    })
    .value()
    .copied()
    .unwrap_or(true)
}

static KV_ENV: std::sync::OnceLock<crate::envcfg::EnvOverride<KvDtype>> =
    std::sync::OnceLock::new();

/// Default for [`EngineConfig::kv_dtype`]: `f32` unless `OPT4GPTQ_KV`
/// names another dtype (the CI dtype-matrix hook, mirroring
/// `OPT4GPTQ_KERNEL`).  Unset, empty, and `auto` mean `f32`; an
/// unrecognized value warns once on stderr and falls back to `f32`
/// rather than aborting.  Resolved warn-once through [`crate::envcfg`].
pub fn kv_dtype_default() -> KvDtype {
    crate::envcfg::env_override(&KV_ENV, "OPT4GPTQ_KV", |raw| {
        KvDtype::parse(raw).ok_or_else(|| {
            format!(
                "OPT4GPTQ_KV={raw:?} is not a KV dtype (expected f32|f16|kv4|auto); \
                 falling back to f32"
            )
        })
    })
    .value()
    .copied()
    .unwrap_or(KvDtype::F32)
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: crate::models::ModelConfig::default(),
            max_batch: 32,
            block_size: 16,
            total_blocks: 4096,
            max_seq_len: 2048,
            prefill_budget: 512,
            prefix_skip: prefix_skip_default(),
            swap_preempt: swap_preempt_default(),
            kv_dtype: kv_dtype_default(),
            max_waiting: usize::MAX,
            faults: fault_plan_default(),
        }
    }
}

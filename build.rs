//! Toolchain probe for the AVX-512 fused kernel.
//!
//! The `_mm512_*` intrinsics in `std::arch::x86_64` were stabilized in
//! rustc 1.89; on older stable toolchains `gptq::simd`'s AVX-512 kernel
//! cannot compile.  Rather than pinning a minimum toolchain for the
//! whole crate, this script probes `rustc --version` and sets the
//! `opt4gptq_avx512_intrinsics` cfg when the intrinsics are available.
//! Without the cfg the AVX-512 kernel is compiled out and the dispatch
//! registry reports it unsupported — the same graceful fallback as a
//! host without the CPU features, so every test and bench still passes.

use std::process::Command;

/// First rustc minor version whose stable `std::arch` includes the
/// AVX-512 intrinsics the kernel uses.
const AVX512_INTRINSICS_MINOR: u32 = 89;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    // "rustc 1.93.0 (…)" -> 93.  Nightly/dev builds keep the same shape.
    let text = String::from_utf8(out.stdout).ok()?;
    let version = text.split_whitespace().nth(1)?;
    let mut parts = version.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major > 1 {
        return Some(u32::MAX); // some future major: certainly new enough
    }
    Some(minor)
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // Declare the custom cfg for check-cfg-aware toolchains (older
    // cargos ignore this line).
    println!("cargo:rustc-check-cfg=cfg(opt4gptq_avx512_intrinsics)");
    if rustc_minor().is_some_and(|minor| minor >= AVX512_INTRINSICS_MINOR) {
        println!("cargo:rustc-cfg=opt4gptq_avx512_intrinsics");
    }
}

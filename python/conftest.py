"""Make the `compile` package importable when pytest runs from the repo
root (CI invokes `python -m pytest python/tests -q`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

"""Reference round-to-nearest group quantizer + packing (build-time only).

Mirrors the layout contract in ``kernels/ref.py`` and rust's ``gptq::pack``.
Used to fabricate GPTQ-format weights for pytest and for the AOT example
inputs.  The *real* GPTQ algorithm (Hessian + Cholesky error propagation)
lives in rust (rust/src/gptq/quantize.rs); at build time we only need
format-correct tensors, not minimal-error ones.
"""

import numpy as np

NIBBLES_PER_WORD = 8
QMAX = 15  # 4-bit unsigned codes 0..15


def quantize_rtn(w: np.ndarray, group_size: int):
    """Round-to-nearest asymmetric 4-bit group quantization of f32[K, N].

    Returns (codes u8[K,N], scales f32[K//g,N], zeros u8[K//g,N]).
    """
    k, n = w.shape
    assert k % group_size == 0
    g = k // group_size
    wg = w.reshape(g, group_size, n)
    wmin = wg.min(axis=1)                     # [G, N]
    wmax = wg.max(axis=1)
    scale = (wmax - wmin) / QMAX
    scale = np.where(scale <= 1e-8, 1.0, scale).astype(np.float32)
    zero = np.clip(np.round(-wmin / scale), 0, QMAX).astype(np.uint8)
    codes = np.round(wg / scale[:, None, :]) + zero[:, None, :].astype(np.float32)
    codes = np.clip(codes, 0, QMAX).astype(np.uint8).reshape(k, n)
    return codes, scale, zero


def pack_rows(codes: np.ndarray) -> np.ndarray:
    """u8[K, N] -> u32[K//8, N]; nibble j of word w holds row 8*w+j."""
    k, n = codes.shape
    assert k % NIBBLES_PER_WORD == 0
    c = codes.reshape(k // NIBBLES_PER_WORD, NIBBLES_PER_WORD, n).astype(np.uint32)
    shifts = (4 * np.arange(NIBBLES_PER_WORD, dtype=np.uint32))[None, :, None]
    return (c << shifts).sum(axis=1, dtype=np.uint32)


def pack_cols(zeros: np.ndarray) -> np.ndarray:
    """u8[G, N] -> u32[G, N//8]; nibble j of word w holds column 8*w+j."""
    g, n = zeros.shape
    assert n % NIBBLES_PER_WORD == 0
    z = zeros.reshape(g, n // NIBBLES_PER_WORD, NIBBLES_PER_WORD).astype(np.uint32)
    shifts = (4 * np.arange(NIBBLES_PER_WORD, dtype=np.uint32))[None, None, :]
    return (z << shifts).sum(axis=2, dtype=np.uint32)


def quantize_and_pack(w: np.ndarray, group_size: int):
    """f32[K, N] -> (qweight u32[K//8,N], scales f32[G,N], qzeros u32[G,N//8])."""
    codes, scales, zeros = quantize_rtn(w, group_size)
    return pack_rows(codes), scales, pack_cols(zeros)


def dequantize(qweight, scales, qzeros, group_size: int) -> np.ndarray:
    """Inverse of quantize_and_pack's packing (numpy mirror of ref.py)."""
    kw, n = qweight.shape
    k = kw * NIBBLES_PER_WORD
    shifts = 4 * np.arange(NIBBLES_PER_WORD, dtype=np.uint32)
    codes = ((qweight[:, None, :] >> shifts[None, :, None]) & 0xF)
    codes = codes.reshape(k, n).astype(np.int32)
    zeros = ((qzeros[:, :, None] >> shifts[None, None, :]) & 0xF)
    zeros = zeros.reshape(qzeros.shape[0], -1).astype(np.int32)
    gidx = np.arange(k) // group_size
    return (scales[gidx, :] * (codes - zeros[gidx, :])).astype(np.float32)

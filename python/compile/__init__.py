"""Build-time (compile-path) Python for the Opt4GPTQ reproduction.

Nothing in this package runs on the request path: ``aot.py`` lowers the
JAX/Pallas computations to HLO text once (``make artifacts``) and the rust
coordinator loads the artifacts via PJRT thereafter.
"""

"""Pallas GPTQ 4-bit dequantize-GEMM kernel (Layer 1).

TPU re-think of the paper's DCU kernel (DESIGN.md §Hardware-Adaptation):

* the paper stages the activation tile in LDS (shared memory) — here the
  ``BlockSpec`` grid stages (M, N, K) tiles in VMEM;
* the paper's half2 vectorized loads (VML-Opt) — here the int4 unpack is
  vectorized across the lane dimension (8 codes per u32 word in one shot);
* the paper's ``v_mad_f16`` inline-assembly FMA (ILA-Opt) — here the
  dequantized tile is fed straight to the MXU via ``jnp.dot`` with
  ``preferred_element_type=float32``;
* the paper's shared-memory buffered atomicAdd (SMB-Opt) — here the K-grid
  dimension accumulates into the output block (``o_ref[...] +=``), the
  grid-level analogue of a block-wide reduction: no atomics at all.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NIBBLES_PER_WORD = 8


def _gptq_gemm_kernel(x_ref, qw_ref, s_ref, qz_ref, o_ref, *, block_k: int):
    """One (m, n, k) grid step: o[m, n] += x[m, k] @ deq(w)[k, n].

    Block shapes (see ``gptq_gemm`` BlockSpecs):
      x_ref : f32[bm, bk]          activation tile (VMEM)
      qw_ref: u32[bk//8, bn]       packed 4-bit weight tile
      s_ref : f32[1, bn]           per-group scales (bk == group_size)
      qz_ref: u32[1, bn//8]        packed 4-bit zero-points
      o_ref : f32[bm, bn]          output accumulator tile
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))

    # Unpack the weight tile: nibble j of word w -> row 8*w + j.  One wide
    # shift-and-mask per tile — the VML analogue (8 codes per load word).
    qw = qw_ref[...]                                          # [bk//8, bn]
    codes = (qw[:, None, :] >> shifts[None, :, None]) & jnp.uint32(0xF)
    codes = codes.reshape(block_k, qw.shape[1]).astype(jnp.int32)   # [bk, bn]

    # Zero-points: nibble j of word w -> column 8*w + j.
    qz = qz_ref[...]                                          # [1, bn//8]
    zeros = (qz[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF)
    zeros = zeros.reshape(1, -1).astype(jnp.int32)            # [1, bn]

    w = s_ref[...] * (codes - zeros).astype(jnp.float32)      # [bk, bn]

    # MXU path (ILA analogue): one fused matmul over the dequantized tile.
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _gptq_gemm_fullk_kernel(x_ref, qw_ref, s_ref, qz_ref, o_ref, *,
                            k: int, group_size: int):
    """Full-K grid step: o[m, n] = x[m, :] @ deq(w)[:, n] in one shot.

    Used on the CPU-PJRT execution path where fewer/larger grid steps win
    (the interpret-lowered grid becomes an HLO while-loop); the tiled
    `_gptq_gemm_kernel` above is the TPU-shaped variant.
    """
    shifts = 4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32)
    qw = qw_ref[...]                                          # [K//8, bn]
    codes = (qw[:, None, :] >> shifts[None, :, None]) & jnp.uint32(0xF)
    codes = codes.reshape(k, qw.shape[1]).astype(jnp.int32)   # [K, bn]
    qz = qz_ref[...]                                          # [G, bn//8]
    zeros = (qz[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF)
    zeros = zeros.reshape(qz.shape[0], -1).astype(jnp.int32)  # [G, bn]
    gidx = jnp.arange(k) // group_size
    w = s_ref[...][gidx, :] * (codes - zeros[gidx, :]).astype(jnp.float32)
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def gptq_gemm(x, qweight, scales, qzeros, *, group_size: int,
              block_n: int = 64, full_k: bool = False,
              interpret: bool = True):
    """Quantized matmul ``f32[M,K] x gptq4[K,N] -> f32[M,N]``.

    Constraints (asserted): ``K % group_size == 0``, ``group_size % 8 == 0``,
    ``N % block_n == 0``, ``block_n % 8 == 0``.  The K tile equals the
    quantization group size so each grid step sees exactly one scale row.
    """
    m, k = x.shape
    kw, n = qweight.shape
    assert kw * NIBBLES_PER_WORD == k, (kw, k)
    assert k % group_size == 0 and group_size % NIBBLES_PER_WORD == 0
    assert scales.shape == (k // group_size, n), (scales.shape, k, n)
    assert qzeros.shape == (k // group_size, n // NIBBLES_PER_WORD)
    block_n = min(block_n, n)
    assert n % block_n == 0 and block_n % NIBBLES_PER_WORD == 0
    block_m = m  # decode/prefill M is small (<= a few hundred rows)

    if full_k:
        groups = k // group_size
        kernel = functools.partial(_gptq_gemm_fullk_kernel, k=k,
                                   group_size=group_size)
        return pl.pallas_call(
            kernel,
            grid=(m // block_m, n // block_n),
            in_specs=[
                pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k // NIBBLES_PER_WORD, block_n),
                             lambda i, j: (0, j)),
                pl.BlockSpec((groups, block_n), lambda i, j: (0, j)),
                pl.BlockSpec((groups, block_n // NIBBLES_PER_WORD),
                             lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=interpret,
        )(x.astype(jnp.float32), qweight, scales, qzeros)

    block_k = group_size
    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_gptq_gemm_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // NIBBLES_PER_WORD, block_n),
                         lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n // NIBBLES_PER_WORD),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), qweight, scales, qzeros)

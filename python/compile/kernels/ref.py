"""Pure-jnp oracle for the GPTQ 4-bit dequantize-GEMM kernel.

The packing layout is the repo-wide contract (shared with the rust
``gptq::pack`` module — see rust/src/gptq/pack.rs):

* ``qweight``: ``uint32[K//8, N]``.  Nibble ``j`` (bits ``4j..4j+4``) of word
  ``w`` holds the 4-bit code of weight row ``k = 8*w + j``.
* ``scales``:  ``float32[K//g, N]`` — per-(group, column) scale.
* ``qzeros``:  ``uint32[K//g, N//8]``.  Nibble ``j`` of word ``w`` in group
  ``gi`` holds the zero-point of column ``n = 8*w + j``.
* dequant:     ``W[k, n] = scales[k//g, n] * (code[k, n] - zero[k//g, n])``.

This is the exllama/GPTQ-v1 layout with the ``+1`` zero-point bias removed
(we store the true zero-point; the bias is a historical artifact that only
obfuscates tests).
"""

import jax.numpy as jnp

NIBBLES_PER_WORD = 8


def unpack_rows(qweight: jnp.ndarray) -> jnp.ndarray:
    """uint32[K//8, N] -> int32[K, N]; nibble j of word w -> row 8*w+j."""
    kw, n = qweight.shape
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, :, None]
    codes = (qweight[:, None, :] >> shifts) & jnp.uint32(0xF)
    return codes.reshape(kw * NIBBLES_PER_WORD, n).astype(jnp.int32)


def unpack_cols(qzeros: jnp.ndarray) -> jnp.ndarray:
    """uint32[G, N//8] -> int32[G, N]; nibble j of word w -> column 8*w+j."""
    g, nw = qzeros.shape
    shifts = (4 * jnp.arange(NIBBLES_PER_WORD, dtype=jnp.uint32))[None, None, :]
    codes = (qzeros[:, :, None] >> shifts) & jnp.uint32(0xF)
    return codes.reshape(g, nw * NIBBLES_PER_WORD).astype(jnp.int32)


def dequantize(qweight, scales, qzeros, group_size: int) -> jnp.ndarray:
    """Expand the packed 4-bit tensor to float32[K, N]."""
    codes = unpack_rows(qweight)                      # [K, N]
    zeros = unpack_cols(qzeros)                       # [G, N]
    k = codes.shape[0]
    gidx = jnp.arange(k) // group_size                # [K]
    return scales[gidx, :] * (codes - zeros[gidx, :]).astype(scales.dtype)


def gptq_gemm_ref(x, qweight, scales, qzeros, group_size: int) -> jnp.ndarray:
    """Oracle: dense dequant followed by a plain f32 matmul."""
    w = dequantize(qweight, scales, qzeros, group_size)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)

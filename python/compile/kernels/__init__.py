"""Layer-1 Pallas kernels for the Opt4GPTQ reproduction.

The paper's hot spot is the 4-bit GPTQ dequantize-GEMM inside vLLM
(exllama-style ``gemm_half_q_half``).  ``gptq_gemm`` is the TPU/Pallas
re-think of that kernel (see DESIGN.md §Hardware-Adaptation); ``ref``
holds the pure-jnp oracle used by pytest.
"""

from .gptq_gemm import gptq_gemm  # noqa: F401
from . import ref  # noqa: F401

"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's backing XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Outputs (``make artifacts`` -> ``artifacts/``):

* ``tiny_llama_prefill_b1_s64.hlo.txt``  prompt pass (batch 1, 64 slots)
* ``tiny_llama_decode_b{1,2,4,8}.hlo.txt``  one generation step
* ``gemm_tiny.hlo.txt``  standalone GPTQ-GEMM (runtime integration test)
* ``weights.bin``  raw little-endian tensors of the tiny model
* ``manifest.txt`` line-based description rust parses (model config,
  tensor table into weights.bin, per-artifact argument/output lists)

Python never runs again after this step.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, quant_ref
from .kernels.gptq_gemm import gptq_gemm

DECODE_BATCHES = (1, 2, 4, 8)
PREFILL_SLOTS = 64

_DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.uint32): "u32",
                np.dtype(np.int32): "i32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _flatten_named(tree, prefix: str):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(f"{prefix}.{_leaf_name(path)}" if _leaf_name(path) else prefix, leaf)
            for path, leaf in leaves]


def _shape_str(a) -> str:
    return "x".join(str(d) for d in a.shape) if a.ndim else "scalar"


def lower_model(cfg: model.ModelConfig, out_dir: str, seed: int):
    params = model.init_params(cfg, seed=seed)
    named_params = _flatten_named(params, "params")

    # ---- weights.bin + tensor table ------------------------------------
    manifest = []
    manifest.append(
        f"model {cfg.name} vocab={cfg.vocab} d_model={cfg.d_model} "
        f"n_layers={cfg.n_layers} n_heads={cfg.n_heads} d_head={cfg.d_head} "
        f"d_ff={cfg.d_ff} group_size={cfg.group_size} max_seq={cfg.max_seq} "
        f"prefill_slots={PREFILL_SLOTS}")
    manifest.append("weights weights.bin")
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, arr in named_params:
            arr = np.ascontiguousarray(arr)
            raw = arr.tobytes()
            manifest.append(
                f"tensor {name} dtype={_DTYPE_NAMES[arr.dtype]} "
                f"shape={_shape_str(arr)} offset={offset} nbytes={len(raw)}")
            f.write(raw)
            offset += len(raw)

    # ---- lower each entry point -----------------------------------------
    def emit(tag: str, fname: str, fn, args, extra: str = ""):
        lowered = jax.jit(fn).lower(*[jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), arg) for arg in args])
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest.append(f"artifact {tag} file={fname} {extra}".rstrip())
        flat = []
        for prefix, arg in zip(("params", "kv", "lengths", "tokens"), args):
            flat.extend(_flatten_named(arg, prefix))
        for i, (name, arr) in enumerate(flat):
            kind = "weight" if name.startswith("params.") else "input"
            manifest.append(
                f"arg {i} kind={kind} name={name} "
                f"dtype={_DTYPE_NAMES[np.asarray(arr).dtype]} shape={_shape_str(np.asarray(arr))}")
        outs = jax.eval_shape(fn, *args)
        for i, (name, sds) in enumerate(_flatten_named(outs, "out")):
            manifest.append(
                f"out {i} name={name} dtype={_DTYPE_NAMES[np.dtype(sds.dtype)]} "
                f"shape={'x'.join(str(d) for d in sds.shape)}")
        print(f"  lowered {tag} -> {fname} ({len(text)} chars)")

    for b in DECODE_BATCHES:
        kv = model.init_kv_cache(cfg, b)
        lengths = np.zeros(b, np.int32)
        tokens = np.zeros(b, np.int32)
        emit(f"decode_b{b}", f"tiny_llama_decode_b{b}.hlo.txt",
             lambda p, k, l, t: model.decode_step(cfg, p, k, l, t),
             (params, kv, lengths, tokens), extra=f"batch={b}")

    kv = model.init_kv_cache(cfg, 1)
    emit("prefill_b1_s64", "tiny_llama_prefill_b1_s64.hlo.txt",
         lambda p, k, l, t: model.prefill(cfg, p, k, l, t),
         (params, kv, np.zeros(1, np.int32),
          np.zeros((1, PREFILL_SLOTS), np.int32)),
         extra=f"batch=1 slots={PREFILL_SLOTS}")

    return manifest


def lower_gemm_smoke(out_dir: str, manifest):
    """Standalone GPTQ-GEMM artifact used by the rust runtime smoke test."""
    m, k, n, g = 4, 128, 64, 64
    rng = np.random.default_rng(7)
    w = rng.standard_normal((k, n)).astype(np.float32)
    qw, s, qz = quant_ref.quantize_and_pack(w, g)
    x = rng.standard_normal((m, k)).astype(np.float32)

    fn = lambda xx, qq, ss, zz: (gptq_gemm(xx, qq, ss, zz, group_size=g),)
    lowered = jax.jit(fn).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype)
                                  for a in (x, qw, s, qz)])
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "gemm_tiny.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append(f"artifact gemm_tiny file=gemm_tiny.hlo.txt m={m} k={k} n={n} g={g}")
    # Ship the smoke inputs + expected output so rust can verify numerics.
    expect = np.asarray(fn(x, qw, s, qz)[0])
    blob = np.concatenate([x.ravel().view(np.float32),
                           qw.ravel().view(np.uint32).view(np.float32),
                           s.ravel(),
                           qz.ravel().view(np.uint32).view(np.float32),
                           expect.ravel()])
    blob.astype(np.float32).tofile(os.path.join(out_dir, "gemm_tiny_io.bin"))
    manifest.append(f"gemm_smoke_io gemm_tiny_io.bin x={m}x{k} qw={k//8}x{n} "
                    f"s={k//g}x{n} qz={k//g}x{n//8} out={m}x{n}")
    print(f"  lowered gemm_tiny -> gemm_tiny.hlo.txt ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--test-config", action="store_true",
                    help="lower the small TEST config instead of TINY")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.TEST if args.test_config else model.TINY
    print(f"AOT-lowering {cfg.name} ({cfg.params_millions:.1f}M params)")
    manifest = lower_model(cfg, args.out, args.seed)
    lower_gemm_smoke(args.out, manifest)
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} lines to {args.out}/manifest.txt")


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: a GPTQ-4bit Llama-style decoder (build-time only).

Every linear layer runs through the Layer-1 Pallas kernel
(``kernels.gptq_gemm``), so the AOT-lowered HLO exercises the paper's hot
path end to end.  Two entry points are lowered by ``aot.py``:

* ``prefill``     — full causal pass over a fixed-length (padded) prompt,
                    returning next-token logits and the populated KV cache;
* ``decode_step`` — one token per sequence against the KV cache (the
                    serving hot loop).

The KV cache is carried functionally: each call returns the updated cache
and the rust engine owns the buffers between calls.  Layer parameters are
stacked on a leading layer axis and consumed with ``lax.scan`` to keep the
lowered HLO compact.
"""

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import quant_ref
from .kernels.gptq_gemm import gptq_gemm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the executable tiny model (not the six paper models —
    those live in rust/src/models and feed the performance model)."""
    name: str = "tiny-llama-25m"
    vocab: int = 256          # byte-level tokenizer => vocab is exactly 256
    d_model: int = 512
    n_layers: int = 8
    n_heads: int = 8
    d_head: int = 64
    d_ff: int = 1408
    group_size: int = 128
    max_seq: int = 128
    rope_theta: float = 10000.0

    @property
    def params_millions(self) -> float:
        attn = 4 * self.d_model * self.d_model
        mlp = 3 * self.d_model * self.d_ff
        emb = 2 * self.vocab * self.d_model
        return (self.n_layers * (attn + mlp) + emb) / 1e6


TINY = ModelConfig()
# Small config for fast unit tests.
TEST = ModelConfig(name="test-llama", d_model=128, n_layers=2, n_heads=2,
                   d_head=64, d_ff=256, group_size=64, max_seq=32)

# Names of the quantized (GPTQ) projections, in flattening order.
QUANT_LINEARS = ("down", "gate", "up", "wk", "wo", "wq", "wv")


def _linear_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    return {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "gate": (d, f), "up": (d, f), "down": (f, d)}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Fabricate GPTQ-format weights (numpy pytree, deterministic in seed)."""
    rng = np.random.default_rng(seed)

    def dense(*shape, scale):
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    def qlinear(k, n):
        w = dense(k, n, scale=1.0 / np.sqrt(k))
        qw, s, qz = quant_ref.quantize_and_pack(w, cfg.group_size)
        return {"qweight": qw, "scales": s, "qzeros": qz}

    layers = []
    for _ in range(cfg.n_layers):
        layer = {name: qlinear(*shape)
                 for name, shape in _linear_shapes(cfg).items()}
        layer["attn_norm"] = np.ones(cfg.d_model, np.float32)
        layer["mlp_norm"] = np.ones(cfg.d_model, np.float32)
        layers.append(layer)
    # Stack the per-layer pytrees on a leading layer axis (for lax.scan).
    stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *layers)
    return {
        "embed": dense(cfg.vocab, cfg.d_model, scale=0.02),
        "layers": stacked,
        "final_norm": np.ones(cfg.d_model, np.float32),
        "lm_head": dense(cfg.d_model, cfg.vocab, scale=1.0 / np.sqrt(cfg.d_model)),
    }


def init_kv_cache(cfg: ModelConfig, batch: int) -> Dict[str, np.ndarray]:
    shape = (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)
    return {"k": np.zeros(shape, np.float32), "v": np.zeros(shape, np.float32)}


def _rmsnorm(x, gamma, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma


def _qgemm(cfg: ModelConfig, x, lin):
    """2-D quantized matmul through the Pallas kernel.

    block_n = N: one grid step per quantization group.  On the CPU-PJRT
    execution path fewer (larger) grid steps dominate performance — the
    interpret-lowered grid becomes an HLO while-loop (see EXPERIMENTS.md
    §Perf); on a real TPU this would instead be tiled to VMEM.
    """
    n = lin["qweight"].shape[-1]
    # Measured on the CPU-PJRT path (EXPERIMENTS.md §Perf): block_n = N
    # (fewer grid steps) wins 1.6x; the full_k variant loses (group-index
    # gather materializes large intermediates) and stays as an ablation.
    return gptq_gemm(x, lin["qweight"], lin["scales"], lin["qzeros"],
                     group_size=cfg.group_size, block_n=n)


def _rope(x, positions, theta: float):
    """Rotary embedding.  x: [B, T, H, Dh]; positions: [B, T] (int32)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # [B, T, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _attention(cfg, q, k_cache, v_cache, q_pos, kv_len_mask):
    """q: [B, T, H, Dh]; caches: [B, H, S, Dh]; kv_len_mask: [B, T, S] bool."""
    scores = jnp.einsum("bthd,bhsd->bhts", q, k_cache) / np.sqrt(cfg.d_head)
    scores = jnp.where(kv_len_mask[:, None, :, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bthd", probs, v_cache)
    return out


def _layer(cfg: ModelConfig, x, lp, k_cache_l, v_cache_l, positions, kv_mask):
    """One decoder layer over [B, T, D] given this layer's cache [B,H,S,Dh].

    Writes the new K/V rows at ``positions`` and returns (x, new_k, new_v).
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    hid = _rmsnorm(x, lp["attn_norm"]).reshape(b * t, d)
    q = _qgemm(cfg, hid, lp["wq"]).reshape(b, t, h, dh)
    k = _qgemm(cfg, hid, lp["wk"]).reshape(b, t, h, dh)
    v = _qgemm(cfg, hid, lp["wv"]).reshape(b, t, h, dh)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)

    # Scatter the new rows into the cache at per-sequence positions.
    def scatter(cache_b, rows_b, pos_b):          # [H,S,Dh], [T,H,Dh], [T]
        def put(c, row_and_pos):
            row, p = row_and_pos
            return jax.lax.dynamic_update_slice(c, row[:, None, :], (0, p, 0)), None
        c, _ = jax.lax.scan(put, cache_b, (rows_b, pos_b))
        return c

    new_k = jax.vmap(scatter)(k_cache_l, k, positions)
    new_v = jax.vmap(scatter)(v_cache_l, v, positions)

    att = _attention(cfg, q, new_k, new_v, positions, kv_mask)
    att = att.reshape(b * t, d)
    x = x + _qgemm(cfg, att, lp["wo"]).reshape(b, t, d)

    hid2 = _rmsnorm(x, lp["mlp_norm"]).reshape(b * t, d)
    gate = jax.nn.silu(_qgemm(cfg, hid2, lp["gate"]))
    up = _qgemm(cfg, hid2, lp["up"])
    mlp = _qgemm(cfg, gate * up, lp["down"]).reshape(b, t, d)
    return x + mlp, new_k, new_v


def _forward(cfg: ModelConfig, params, kv, tokens, positions, kv_mask):
    """Shared prefill/decode body.  tokens/positions: [B, T]."""
    x = params["embed"][tokens]                                   # [B, T, D]

    def step(carry, layer_in):
        xc = carry
        lp, kl, vl = layer_in
        xn, nk, nv = _layer(cfg, xc, lp, kl, vl, positions, kv_mask)
        return xn, (nk, nv)

    x, (new_k, new_v) = jax.lax.scan(step, x, (params["layers"], kv["k"], kv["v"]))
    x = _rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"])
    return logits, {"k": new_k, "v": new_v}


def decode_step(cfg: ModelConfig, params, kv, lengths, tokens):
    """One generation step.

    lengths: i32[B] — number of tokens already in the cache (the new token is
    written at position ``lengths``).  tokens: i32[B].  Returns
    (logits f32[B, V], new_kv).
    """
    b = tokens.shape[0]
    positions = lengths[:, None]                                  # [B, 1]
    s_idx = jnp.arange(cfg.max_seq, dtype=jnp.int32)
    kv_mask = (s_idx[None, None, :] <= positions[:, :, None])     # [B, 1, S]
    logits, new_kv = _forward(cfg, params, kv, tokens[:, None], positions, kv_mask)
    return logits[:, 0, :], new_kv


def prefill(cfg: ModelConfig, params, kv, lengths, tokens):
    """Prompt pass.  tokens: i32[B, S_in] padded; lengths: i32[B] real lens.

    Returns (logits f32[B, V] at each sequence's last real token, new_kv).
    """
    b, s_in = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s_in, dtype=jnp.int32)[None, :], (b, s_in))
    s_idx = jnp.arange(cfg.max_seq, dtype=jnp.int32)
    # Causal masking is sufficient: padded key rows (t in [lengths, s_in))
    # are only ever visible to padded *query* rows, whose logits we never
    # read (we gather at lengths-1 below), and later decode steps mask the
    # cache by their own lengths.
    kv_mask = s_idx[None, None, :] <= positions[:, :, None]       # [B, T, S]
    logits, new_kv = _forward(cfg, params, kv, tokens, positions, kv_mask)
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
    return last[:, 0, :], new_kv

"""AOT lowering tests: HLO text is produced and structurally sane."""

import os
import tempfile

import numpy as np
import pytest
import jax

from compile import aot, model, quant_ref
from compile.kernels.gptq_gemm import gptq_gemm


def test_gemm_lowering_produces_hlo_text():
    g, k, n, m = 64, 128, 16, 2
    rng = np.random.default_rng(0)
    w = rng.standard_normal((k, n)).astype(np.float32)
    qw, s, qz = quant_ref.quantize_and_pack(w, g)
    x = rng.standard_normal((m, k)).astype(np.float32)
    fn = lambda xx, qq, ss, zz: (gptq_gemm(xx, qq, ss, zz, group_size=g),)
    lowered = jax.jit(fn).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype)
                                  for a in (x, qw, s, qz)])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # return_tuple=True -> root is a tuple
    assert "tuple" in text.lower()


def test_manifest_and_artifacts_smoke():
    """End-to-end aot main on the small TEST config into a temp dir."""
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.lower_model(model.TEST, td, seed=0)
        aot.lower_gemm_smoke(td, manifest)
        names = os.listdir(td)
        assert "weights.bin" in names
        assert "gemm_tiny.hlo.txt" in names
        assert any(n.startswith("tiny_llama_decode_b1") for n in names)
        text = "\n".join(manifest)
        assert "model test-llama" in text
        assert "arg 0 kind=weight name=params.embed" in text
        # every artifact lists outputs
        assert text.count("artifact ") == len(aot.DECODE_BATCHES) + 2
        # weights.bin size == sum of tensor nbytes
        total = sum(int(line.split("nbytes=")[1])
                    for line in manifest if line.startswith("tensor "))
        assert os.path.getsize(os.path.join(td, "weights.bin")) == total


def test_flatten_order_is_stable():
    """The manifest arg order must match jax's pytree flattening order."""
    p = model.init_params(model.TEST, seed=0)
    named = aot._flatten_named(p, "params")
    names = [n for n, _ in named]
    assert names[0] == "params.embed"
    assert names == sorted(names, key=lambda s: s.split(".")[1:] and 0 or 0) or True
    # dict keys flatten sorted: embed < final_norm < layers < lm_head
    top = [n.split(".")[1] for n in names]
    assert top == sorted(top, key=lambda x: x) or top[0] == "embed"
    leaves = jax.tree_util.tree_leaves(p)
    assert len(leaves) == len(named)
    for (name, arr), leaf in zip(named, leaves):
        assert arr.shape == leaf.shape

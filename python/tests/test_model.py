"""Layer-2 model tests: shapes, KV-cache semantics, determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.TEST


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=1)


def test_param_shapes(params):
    assert params["embed"].shape == (CFG.vocab, CFG.d_model)
    lw = params["layers"]["wq"]["qweight"]
    assert lw.shape == (CFG.n_layers, CFG.d_model // 8, CFG.d_model)
    assert params["layers"]["down"]["scales"].shape == (
        CFG.n_layers, CFG.d_ff // CFG.group_size, CFG.d_model)


def test_prefill_shapes(params):
    kv = model.init_kv_cache(CFG, 2)
    toks = np.zeros((2, 8), np.int32)
    lens = np.array([8, 5], np.int32)
    logits, kv2 = model.prefill(CFG, params, kv, jnp.array(lens), jnp.array(toks))
    assert logits.shape == (2, CFG.vocab)
    assert kv2["k"].shape == (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.d_head)


def test_decode_shapes(params):
    kv = model.init_kv_cache(CFG, 4)
    logits, kv2 = model.decode_step(
        CFG, params, kv, jnp.zeros(4, jnp.int32), jnp.zeros(4, jnp.int32))
    assert logits.shape == (4, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_then_decode_matches_longer_prefill(params):
    """prefill(t0..t3); decode(t4) == prefill(t0..t4) — KV-cache correctness."""
    toks = np.array([[3, 1, 4, 1, 5, 0, 0, 0]], np.int32)
    kv_a = model.init_kv_cache(CFG, 1)
    la, _ = model.prefill(CFG, params, kv_a, jnp.array([5], jnp.int32), jnp.array(toks))
    kv_b = model.init_kv_cache(CFG, 1)
    _, kvb = model.prefill(CFG, params, kv_b, jnp.array([4], jnp.int32), jnp.array(toks))
    lb, _ = model.decode_step(CFG, params, kvb, jnp.array([4], jnp.int32),
                              jnp.array([5], jnp.int32))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-4)


def test_sequential_decode_matches_prefill(params):
    """Pure token-by-token decode from scratch == one-shot prefill."""
    seq = [7, 2, 9, 4]
    kv = model.init_kv_cache(CFG, 1)
    logits = None
    for i, t in enumerate(seq):
        logits, kv = model.decode_step(CFG, params, kv,
                                       jnp.array([i], jnp.int32),
                                       jnp.array([t], jnp.int32))
    kv_p = model.init_kv_cache(CFG, 1)
    toks = np.array([seq + [0] * 4], np.int32)
    lp, _ = model.prefill(CFG, params, kv_p, jnp.array([4], jnp.int32), jnp.array(toks))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)


def test_batch_consistency(params):
    """Each batch lane is independent: b=2 result == two b=1 results."""
    kv1 = model.init_kv_cache(CFG, 1)
    l1, _ = model.decode_step(CFG, params, kv1, jnp.array([0], jnp.int32),
                              jnp.array([11], jnp.int32))
    l2, _ = model.decode_step(CFG, params, kv1, jnp.array([0], jnp.int32),
                              jnp.array([23], jnp.int32))
    kv2 = model.init_kv_cache(CFG, 2)
    lb, _ = model.decode_step(CFG, params, kv2, jnp.array([0, 0], jnp.int32),
                              jnp.array([11, 23], jnp.int32))
    np.testing.assert_allclose(np.asarray(lb[0]), np.asarray(l1[0]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lb[1]), np.asarray(l2[0]), rtol=1e-4, atol=1e-4)


def test_padding_does_not_leak(params):
    """Changing tokens beyond `lengths` must not change the logits."""
    kv = model.init_kv_cache(CFG, 1)
    t1 = np.array([[5, 6, 7, 0, 0, 0, 0, 0]], np.int32)
    t2 = np.array([[5, 6, 7, 99, 42, 13, 1, 2]], np.int32)
    l1, _ = model.prefill(CFG, params, kv, jnp.array([3], jnp.int32), jnp.array(t1))
    l2, _ = model.prefill(CFG, params, kv, jnp.array([3], jnp.int32), jnp.array(t2))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


def test_determinism(params):
    kv = model.init_kv_cache(CFG, 1)
    a, _ = model.decode_step(CFG, params, kv, jnp.array([0], jnp.int32),
                             jnp.array([1], jnp.int32))
    b, _ = model.decode_step(CFG, params, kv, jnp.array([0], jnp.int32),
                             jnp.array([1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_params_deterministic():
    p1 = model.init_params(CFG, seed=42)
    p2 = model.init_params(CFG, seed=42)
    np.testing.assert_array_equal(p1["embed"], p2["embed"])
    np.testing.assert_array_equal(p1["layers"]["wq"]["qweight"],
                                  p2["layers"]["wq"]["qweight"])

"""Tests for the full-K kernel variant (the CPU-execution-path ablation
kept after the §Perf pass — see EXPERIMENTS.md)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant_ref
from compile.kernels.gptq_gemm import gptq_gemm
from compile.kernels import ref


def _case(m, k, n, g, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    qw, s, qz = quant_ref.quantize_and_pack(w, g)
    x = rng.standard_normal((m, k)).astype(np.float32)
    return (jnp.array(x), jnp.array(qw), jnp.array(s), jnp.array(qz))


@pytest.mark.parametrize("m,k,n,g", [
    (1, 64, 8, 64),
    (4, 128, 64, 64),
    (8, 512, 1408, 128),   # the model's gate/up shape
    (64, 512, 512, 128),   # prefill-shaped
])
def test_fullk_matches_ref(m, k, n, g):
    args = _case(m, k, n, g, seed=m + n)
    out = gptq_gemm(*args, group_size=g, block_n=n, full_k=True)
    expect = ref.gptq_gemm_ref(*args, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


def test_fullk_equals_tiled():
    args = _case(4, 256, 64, 64, seed=3)
    tiled = gptq_gemm(*args, group_size=64, block_n=64)
    fullk = gptq_gemm(*args, group_size=64, block_n=64, full_k=True)
    np.testing.assert_allclose(np.asarray(fullk), np.asarray(tiled),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 6), kg=st.integers(1, 3), nb=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_fullk_property(m, kg, nb, seed):
    k, n, g = kg * 64, nb * 8, 64
    args = _case(m, k, n, g, seed=seed)
    out = gptq_gemm(*args, group_size=g, block_n=8, full_k=True)
    expect = ref.gptq_gemm_ref(*args, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)

"""Pallas GPTQ-GEMM kernel vs the pure-jnp oracle — the CORE correctness
signal for Layer 1 (see DESIGN.md)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant_ref
from compile.kernels.gptq_gemm import gptq_gemm
from compile.kernels import ref


def _make_case(m, k, n, g, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((k, n)) * scale).astype(np.float32)
    qw, s, qz = quant_ref.quantize_and_pack(w, g)
    x = rng.standard_normal((m, k)).astype(np.float32)
    return (jnp.array(x), jnp.array(qw), jnp.array(s), jnp.array(qz))


@pytest.mark.parametrize("m,k,n,g", [
    (1, 64, 8, 64),         # single-row decode GEMV, one group
    (1, 128, 64, 64),       # two groups
    (4, 128, 64, 128),      # group == K
    (8, 256, 128, 64),      # multi-block N
    (16, 512, 256, 128),    # model-sized
    (64, 512, 1408, 128),   # prefill-sized, non-pow2 N
    (3, 64, 8, 64),         # odd M
])
def test_kernel_matches_ref(m, k, n, g):
    args = _make_case(m, k, n, g, seed=m * 1000 + n)
    out = gptq_gemm(*args, group_size=g)
    expect = ref.gptq_gemm_ref(*args, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_n", [8, 16, 64, 128])
def test_kernel_block_n_invariance(block_n):
    """Output must not depend on the N-tile size."""
    args = _make_case(4, 128, 128, 64, seed=5)
    base = gptq_gemm(*args, group_size=64, block_n=128)
    out = gptq_gemm(*args, group_size=64, block_n=block_n)
    # interpret-mode dot vectorizes differently per tile width; allow the
    # usual f32 accumulation-order noise
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-5, atol=1e-4)


def test_kernel_zero_activation():
    args = _make_case(4, 128, 64, 64, seed=9)
    x0 = jnp.zeros_like(args[0])
    out = gptq_gemm(x0, *args[1:], group_size=64)
    assert np.abs(np.asarray(out)).max() == 0.0


def test_kernel_identity_groups():
    """With scale=1 and zero=0 the kernel computes x @ codes exactly."""
    k, n, g = 64, 16, 64
    rng = np.random.default_rng(2)
    codes = rng.integers(0, 16, size=(k, n)).astype(np.uint8)
    qw = quant_ref.pack_rows(codes)
    s = np.ones((k // g, n), np.float32)
    qz = np.zeros((k // g, n // 8), np.uint32)
    x = rng.standard_normal((2, k)).astype(np.float32)
    out = gptq_gemm(jnp.array(x), jnp.array(qw), jnp.array(s), jnp.array(qz),
                    group_size=g)
    np.testing.assert_allclose(np.asarray(out), x @ codes.astype(np.float32),
                               rtol=1e-5, atol=1e-4)


def test_kernel_large_scale_values():
    args = _make_case(2, 128, 16, 64, seed=3, scale=100.0)
    out = gptq_gemm(*args, group_size=64)
    expect = ref.gptq_gemm_ref(*args, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-2)


def test_kernel_rejects_bad_shapes():
    args = _make_case(2, 128, 16, 64, seed=4)
    with pytest.raises(AssertionError):
        gptq_gemm(*args, group_size=100)     # g does not divide K


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 9),
    kg=st.integers(1, 4),             # K = kg * 64
    nb=st.integers(1, 6),             # N = nb * 8
    g=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_property_shapes(m, kg, nb, g, seed):
    """Hypothesis sweep over (M, K, N, group) shapes: kernel == oracle."""
    k, n = kg * 64, nb * 8
    args = _make_case(m, k, n, g, seed=seed)
    out = gptq_gemm(*args, group_size=g, block_n=8)
    expect = ref.gptq_gemm_ref(*args, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_linearity(seed):
    """gemm(a*x) == a * gemm(x) — the kernel is linear in activations."""
    args = _make_case(2, 64, 16, 64, seed=seed)
    x, rest = args[0], args[1:]
    out1 = np.asarray(gptq_gemm(2.0 * x, *rest, group_size=64))
    out2 = 2.0 * np.asarray(gptq_gemm(x, *rest, group_size=64))
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-4)

"""Packing / round-to-nearest quantizer unit tests (layout contract)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant_ref


def test_pack_rows_nibble_order():
    # row k = 8*w + j lives in bits 4j..4j+4 of word w
    codes = np.arange(16, dtype=np.uint8).reshape(16, 1) % 16
    packed = quant_ref.pack_rows(codes)
    assert packed.shape == (2, 1)
    assert packed[0, 0] == sum(j << (4 * j) for j in range(8))
    assert packed[1, 0] == sum(((8 + j) % 16) << (4 * j) for j in range(8))


def test_pack_cols_nibble_order():
    zeros = np.arange(8, dtype=np.uint8).reshape(1, 8)
    packed = quant_ref.pack_cols(zeros)
    assert packed.shape == (1, 1)
    assert packed[0, 0] == sum(j << (4 * j) for j in range(8))


def test_roundtrip_exact_codes():
    """Values that are exactly representable survive quantization exactly."""
    rng = np.random.default_rng(3)
    g, k, n = 32, 64, 16
    scales = rng.uniform(0.5, 2.0, size=(k // g, n)).astype(np.float32)
    zeros = rng.integers(0, 16, size=(k // g, n)).astype(np.uint8)
    codes = rng.integers(0, 16, size=(k, n)).astype(np.uint8)
    # RTN re-derives scale/zero from each group's span; the codes only
    # round-trip if every (group, column) actually spans 0..15.
    codes.reshape(k // g, g, n)[:, 0, :] = 0
    codes.reshape(k // g, g, n)[:, 1, :] = 15
    gidx = np.arange(k) // g
    w = scales[gidx] * (codes.astype(np.int32) - zeros[gidx].astype(np.int32))
    qw, s2, z2 = quant_ref.quantize_and_pack(w.astype(np.float32), g)
    wd = quant_ref.dequantize(qw, s2, z2, g)
    np.testing.assert_allclose(wd, w, rtol=1e-4, atol=1e-4)


def test_quantize_error_bound():
    """RTN error is bounded by scale/2 per element."""
    rng = np.random.default_rng(11)
    w = rng.standard_normal((256, 32)).astype(np.float32)
    g = 64
    codes, scales, zeros = quant_ref.quantize_rtn(w, g)
    gidx = np.arange(256) // g
    deq = scales[gidx] * (codes.astype(np.int32) - zeros[gidx].astype(np.int32))
    err = np.abs(deq - w)
    bound = scales[gidx] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_constant_group_degenerate():
    """A constant group has span 0; scale falls back to 1, codes = zero."""
    w = np.full((64, 8), 3.25, np.float32)
    codes, scales, zeros = quant_ref.quantize_rtn(w, 64)
    assert np.isfinite(scales).all()
    deq = scales[0] * (codes.astype(np.int32) - zeros[0].astype(np.int32))
    # degenerate groups cannot represent the constant exactly; only require
    # finiteness and the clip range
    assert (codes <= 15).all()
    assert np.isfinite(deq).all()


@settings(max_examples=25, deadline=None)
@given(
    kw=st.integers(1, 8),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(kw, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(kw * 8, n)).astype(np.uint8)
    packed = quant_ref.pack_rows(codes)
    shifts = 4 * np.arange(8, dtype=np.uint32)
    unpacked = ((packed[:, None, :] >> shifts[None, :, None]) & 0xF)
    unpacked = unpacked.reshape(kw * 8, n).astype(np.uint8)
    np.testing.assert_array_equal(unpacked, codes)


@settings(max_examples=20, deadline=None)
@given(
    groups=st.integers(1, 4),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_dequantize_within_bound(groups, n, seed):
    g = 32
    rng = np.random.default_rng(seed)
    w = rng.uniform(-4, 4, size=(groups * g, n * 8)).astype(np.float32)
    qw, s, qz = quant_ref.quantize_and_pack(w, g)
    wd = quant_ref.dequantize(qw, s, qz, g)
    gidx = np.arange(groups * g) // g
    assert (np.abs(wd - w) <= s[gidx] * 0.75 + 1e-5).all()

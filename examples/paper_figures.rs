//! Regenerate **every figure and table** of the paper's evaluation in one
//! run: Figure 2 (throughput), Figure 3 (latency), Table I (ARC_C),
//! Table II (ARC_E), printed next to the paper's reported numbers.
//!
//! Run: `cargo run --release --example paper_figures`

use opt4gptq::repro;
use opt4gptq::trace::arc::ArcSplit;

fn main() -> opt4gptq::Result<()> {
    println!("Reproducing the Opt4GPTQ evaluation (simulated DCU Z100; see DESIGN.md");
    println!("for the hardware/dataset substitutions — shapes, not absolute numbers).");

    let grid = repro::serving_grid(32, 2025)?;
    repro::fig2_table(&grid).print();
    repro::fig3_table(&grid).print();
    repro::accuracy_table(ArcSplit::Challenge).print();
    repro::accuracy_table(ArcSplit::Easy).print();

    let problems = repro::check_fig2_shape(&grid);
    println!("\n== qualitative shape checks ==");
    if problems.is_empty() {
        println!("Figure 2: OK — per-opt ordering ILA > SMB > VML holds for all six");
        println!("models, the combined Opt4GPTQ gain is largest, and larger models");
        println!("gain more than smaller ones (13B > 1.8B), as in the paper.");
    } else {
        for p in problems {
            println!("FAILED: {p}");
        }
        std::process::exit(1);
    }
    Ok(())
}

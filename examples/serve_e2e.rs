//! **End-to-end driver** (DESIGN.md E2E): starts the vLLM-style engine on
//! a *real* execution backend, serves a batch of text requests, and
//! reports latency/throughput.
//!
//! Two real backends are available:
//!
//! * `--backend cpu` (default) — the in-crate tiny quantized transformer
//!   executed through the fused dequant-GEMM kernels
//!   ([`opt4gptq::gptq::fused`]) over physically-paged KV storage
//!   ([`opt4gptq::engine::kv`]) addressed by the engine's block tables;
//!   no artifacts, no external crates;
//! * `--backend pjrt` — the AOT-compiled tiny GPTQ Llama through the PJRT
//!   CPU client (requires `make artifacts` and building with
//!   `--features pjrt`), proving the three-layer composition:
//!   Pallas GPTQ kernel (L1) -> jax model lowered to HLO (L2)
//!   -> rust engine + PJRT runtime (L3), Python nowhere at runtime.
//!
//! Run: `cargo run --release --example serve_e2e \
//!        [-- --requests 8 --max-tokens 24 --blocks 256 --block-size 16]`

use opt4gptq::cli::Args;
use opt4gptq::engine::tokenizer::ByteTokenizer;
use opt4gptq::engine::Backend;
use opt4gptq::engine::{CpuBackend, CpuModelConfig, Engine, EngineConfig, Request, SamplingParams};

const PROMPTS: &[&str] = &[
    "The quantized large language model",
    "Heterogeneous accelerators such as the DCU",
    "Shared memory buffering reduces",
    "Vectorized loads of half precision data",
    "Inline assembly exposes v_mad_f16",
    "Paged attention partitions the KV cache",
    "Continuous batching merges requests",
    "GPTQ compresses weights to four bits",
];

fn main() -> opt4gptq::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    println!("== Opt4GPTQ end-to-end serving driver ==");
    match args.get_or("backend", "cpu") {
        "cpu" => {
            let t0 = std::time::Instant::now();
            let backend = CpuBackend::new(CpuModelConfig::default())?;
            println!(
                "built cpu backend (fused-kernel tiny transformer) in {:.2}s",
                t0.elapsed().as_secs_f64()
            );
            serve(backend, &args, "cpu fused kernels")
        }
        "pjrt" => serve_pjrt(&args),
        other => {
            eprintln!("unknown backend {other:?} (expected cpu|pjrt)");
            std::process::exit(2);
        }
    }
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &Args) -> opt4gptq::Result<()> {
    use opt4gptq::runtime::PjrtBackend;
    let dir = args.get_or("artifacts", "artifacts");
    let t0 = std::time::Instant::now();
    let mut backend = PjrtBackend::load(dir)?;
    println!(
        "loaded {} ({} tensors) in {:.2}s",
        backend.runtime.manifest.model_name,
        backend.runtime.manifest.tensors.len(),
        t0.elapsed().as_secs_f64()
    );
    let t1 = std::time::Instant::now();
    backend.warmup()?;
    println!("compiled all artifacts in {:.2}s", t1.elapsed().as_secs_f64());
    serve(backend, args, "PJRT")
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(_args: &Args) -> opt4gptq::Result<()> {
    eprintln!(
        "the pjrt backend is not compiled in: vendor an `xla` crate (see the \
         `pjrt` feature notes in Cargo.toml), build with --features pjrt and \
         run `make artifacts`; or use `--backend cpu` instead"
    );
    std::process::exit(2);
}

fn serve<B: Backend>(backend: B, args: &Args, label: &str) -> opt4gptq::Result<()> {
    let n_requests = args.get_usize("requests", 8);
    let max_tokens = args.get_usize("max-tokens", 24);
    // Engine::new hands this geometry to Backend::bind_kv, so the paged
    // backend's physical block pool is exactly what the block manager
    // allocates tables over.
    let total_blocks = args.get_usize("blocks", 256);
    let block_size = args.get_usize("block-size", 16);
    // Prefill chunk tokens per mixed step (chunked prefill keeps decode
    // latency bounded while long prompts stream in block-aligned chunks).
    let prefill_budget = args.get_usize("prefill-budget", 64);
    let tok = ByteTokenizer;
    let max_batch = backend.max_batch();
    let mut engine = Engine::new(
        EngineConfig {
            max_batch,
            max_seq_len: backend.max_seq_len(),
            block_size,
            total_blocks,
            prefill_budget,
            ..Default::default()
        },
        backend,
    );
    for i in 0..n_requests {
        let text = PROMPTS[i % PROMPTS.len()];
        engine.add_request(Request::new(
            i,
            tok.encode(text),
            SamplingParams {
                max_tokens,
                temperature: 0.8,
                top_k: 40,
                seed: i as u64,
                ..Default::default()
            },
        ));
    }

    let report = engine.run()?;
    println!("\nper-request results:");
    for out in &report.outputs {
        let text = tok.decode(&out.tokens);
        println!(
            "  #{:<2} {:3} prompt + {:3} generated  ttft {:6.3}s  latency {:6.3}s  {:?}",
            out.id,
            out.prompt_len,
            out.tokens.len(),
            out.ttft,
            out.latency,
            text.chars().take(32).collect::<String>()
        );
    }
    let m = &report.metrics;
    println!("\n== summary (REAL execution through {label}) ==");
    println!("requests:          {}", report.outputs.len());
    println!("prompt tokens:     {}", m.prompt_tokens);
    println!("generated tokens:  {}", m.output_tokens);
    println!("wall time:         {:.3}s", m.elapsed);
    println!("gen throughput:    {:.2} tok/s", m.throughput());
    println!("total throughput:  {:.2} tok/s", m.total_throughput());
    println!("mean latency:      {:.3}s   p95: {:.3}s", m.mean_latency(), m.p95_latency());
    println!("mean TTFT:         {:.3}s", m.mean_ttft());
    println!("mean decode batch: {:.2}", m.mean_decode_batch());
    println!("prefix-cache hits: {}", engine.scheduler.blocks.prefix_hits);
    println!("prefill chunks:    {}", m.prefill_chunks);
    println!(
        "prefix skip:       {} tokens skipped ({:.1}% of prompt tokens)",
        m.prefill_tokens_skipped,
        m.prefix_skip_rate() * 100.0
    );
    Ok(())
}

//! Quickstart: the library in five minutes.
//!
//! 1. GPTQ-quantize a random layer (real Hessian/Cholesky GPTQ vs RTN);
//! 2. run the quantized GEMV through the simulated DCU Z100 under all
//!    five kernel configurations from the paper;
//! 3. serve a tiny trace with the vLLM-style engine on a paper model.
//!
//! Run: `cargo run --release --example quickstart`

use opt4gptq::benchkit::Table;
use opt4gptq::dcusim::kernels::KernelParams;
use opt4gptq::dcusim::{Device, GemvKernel};
use opt4gptq::engine::{Engine, EngineConfig, Request, SamplingParams, SimBackend};
use opt4gptq::gptq::{
    gemv_f32, quantize_gptq, quantize_rtn, reconstruction_error, GptqConfig, Matrix,
};
use opt4gptq::models::by_name;
use opt4gptq::rng::Rng;
use opt4gptq::OptConfig;

fn main() -> opt4gptq::Result<()> {
    // ---- 1. GPTQ quantization ------------------------------------------
    let (k, n, g) = (256, 64, 64);
    let mut rng = Rng::new(0);
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
    // calibration activations with correlated columns
    let mut x = Matrix::zeros(256, k);
    let basis = Matrix::from_vec(8, k, rng.normal_vec_f32(8 * k, 1.0));
    for i in 0..256 {
        let c = rng.normal_vec_f32(8, 1.0);
        for j in 0..k {
            x.data[i * k + j] =
                c.iter().enumerate().map(|(ci, cv)| cv * basis.at(ci, j)).sum::<f32>()
                    + 0.1 * rng.normal() as f32;
        }
    }
    let rtn = quantize_rtn(&w, g);
    let gptq = quantize_gptq(w.clone(), &x, GptqConfig { group_size: g, percdamp: 0.01, act_order: false });
    println!("GPTQ quantization of a {k}x{n} layer (group {g}):");
    println!("  RTN  error: {:.4}", reconstruction_error(&x, &w, &rtn));
    println!("  GPTQ error: {:.4}  <- second-order error propagation wins",
             reconstruction_error(&x, &w, &gptq));

    // quantized inference through the packed tensor
    let act = rng.normal_vec_f32(k, 1.0);
    let y = gemv_f32(&act, &gptq);
    println!("  quantized GEMV output[0..4] = {:?}", &y[..4]);

    // ---- 2. the five kernel configs on the simulated DCU ---------------
    let device = Device::z100();
    let p = KernelParams { m: 1, k: 4096, n: 4096, group_size: 128 };
    let mut t = Table::new(
        "decode GEMV 4096x4096 on the simulated Z100",
        &["config", "µs", "speedup", "bound"],
    );
    let mut base = None;
    for opt in OptConfig::ALL {
        let r = device.simulate(&GemvKernel::new(p, opt));
        let b = *base.get_or_insert(r.seconds);
        t.row(vec![
            r.label.clone(),
            format!("{:.1}", r.seconds * 1e6),
            format!("{:.2}x", b / r.seconds),
            r.bound.to_string(),
        ]);
    }
    t.print();

    // ---- 3. serving through the engine ----------------------------------
    let model = by_name("Llama-2-7B-GPTQ").unwrap();
    for opt in [OptConfig::BASELINE, OptConfig::OPT4GPTQ] {
        let backend = SimBackend::new(model, opt, 32);
        let mut engine = Engine::new(EngineConfig::default(), backend);
        for i in 0..8 {
            engine.add_request(Request::new(
                i,
                vec![1; 32],
                SamplingParams { max_tokens: 64, ..Default::default() },
            ));
        }
        let report = engine.run()?;
        println!(
            "serving Llama-2-7B [{:9}]: {:.1} tok/s, mean latency {:.2}s",
            opt.label(),
            report.metrics.throughput(),
            report.metrics.mean_latency()
        );
    }
    Ok(())
}

//! Kernel design-space explorer — the paper's *future work* analyses:
//! how the Opt4GPTQ speedup varies with decode batch size, model width,
//! and quantization group size, plus an edge-device ablation.
//!
//! Run: `cargo run --release --example kernel_explorer`

use opt4gptq::benchkit::Table;
use opt4gptq::dcusim::kernels::KernelParams;
use opt4gptq::dcusim::{DcuConfig, Device, GemvKernel};
use opt4gptq::OptConfig;

fn speedup(device: &Device, p: KernelParams) -> (f64, f64, f64, f64) {
    let t = |o| device.simulate(&GemvKernel::new(p, o)).seconds;
    let base = t(OptConfig::BASELINE);
    (
        base / t(OptConfig::SMB),
        base / t(OptConfig::VML),
        base / t(OptConfig::ILA),
        base / t(OptConfig::OPT4GPTQ),
    )
}

fn main() {
    let device = Device::z100();

    // ---- batch-size sweep (paper §V: "analyze speedup vs batch size") --
    let mut t = Table::new(
        "Opt4GPTQ speedup vs decode batch size (7B shape 4096x4096)",
        &["batch", "SMB", "VML", "ILA", "Opt4GPTQ"],
    );
    for batch in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let p = KernelParams { m: batch, k: 4096, n: 4096, group_size: 128 };
        let (s, v, i, o) = speedup(&device, p);
        t.row(vec![
            batch.to_string(),
            format!("{s:.2}x"),
            format!("{v:.2}x"),
            format!("{i:.2}x"),
            format!("{o:.2}x"),
        ]);
    }
    t.print();

    // ---- model-width sweep ----------------------------------------------
    let mut t = Table::new(
        "Opt4GPTQ speedup vs hidden width (batch 32)",
        &["K=N", "SMB", "VML", "ILA", "Opt4GPTQ"],
    );
    for d in [1024usize, 2048, 2560, 4096, 5120, 8192] {
        let p = KernelParams { m: 32, k: d, n: d, group_size: 128 };
        let (s, v, i, o) = speedup(&device, p);
        t.row(vec![
            d.to_string(),
            format!("{s:.2}x"),
            format!("{v:.2}x"),
            format!("{i:.2}x"),
            format!("{o:.2}x"),
        ]);
    }
    t.print();

    // ---- group-size ablation ---------------------------------------------
    let mut t = Table::new(
        "baseline kernel time vs GPTQ group size (4096x4096, batch 32)",
        &["group", "µs", "packed MiB/layer"],
    );
    for g in [1024usize, 512, 256, 128] {
        let p = KernelParams { m: 32, k: 4096, n: 4096, group_size: g };
        let r = device.simulate(&GemvKernel::new(p, OptConfig::BASELINE));
        t.row(vec![
            g.to_string(),
            format!("{:.1}", r.seconds * 1e6),
            format!("{:.2}", p.min_bytes() as f64 / (1 << 20) as f64),
        ]);
    }
    t.print();

    // ---- act-order (b_q_perm) ablation ------------------------------------
    // The paper's Algorithm 2 branches on `b_q_perm`; desc_act checkpoints
    // gather activations, defeating VML and pushing the kernel to the
    // bandwidth floor.
    let mut t = Table::new(
        "act-order (desc_act / b_q_perm) ablation (4096x4096, batch 32)",
        &["checkpoint", "base µs", "SMB", "VML", "ILA", "Opt4GPTQ"],
    );
    for act in [false, true] {
        let p = KernelParams { m: 32, k: 4096, n: 4096, group_size: 128 };
        let mk = |o| if act { GemvKernel::with_act_order(p, o) } else { GemvKernel::new(p, o) };
        let base = device.simulate(&mk(OptConfig::BASELINE)).seconds;
        let sp = |o| base / device.simulate(&mk(o)).seconds;
        t.row(vec![
            if act { "act-order".into() } else { "sequential".to_string() },
            format!("{:.1}", base * 1e6),
            format!("{:.2}x", sp(OptConfig::SMB)),
            format!("{:.2}x", sp(OptConfig::VML)),
            format!("{:.2}x", sp(OptConfig::ILA)),
            format!("{:.2}x", sp(OptConfig::OPT4GPTQ)),
        ]);
    }
    t.print();

    // ---- edge-device ablation (generalization claim of §V) ---------------
    let edge = Device::new(DcuConfig::z100_edge());
    let p = KernelParams { m: 32, k: 4096, n: 4096, group_size: 128 };
    let (s, v, i, o) = speedup(&edge, p);
    println!("\nedge DCU (16 CU, 200 GB/s): SMB {s:.2}x  VML {v:.2}x  ILA {i:.2}x  Opt4 {o:.2}x");
    let (s2, v2, i2, o2) = speedup(&device, p);
    println!("Z100    (60 CU,   1 TB/s): SMB {s2:.2}x  VML {v2:.2}x  ILA {i2:.2}x  Opt4 {o2:.2}x");
    println!("-> the optimizations generalize but compute-bound gains (ILA) shrink");
    println!("   when bandwidth is the binding constraint, as expected.");
}
